//! The ad-hoc baseline: what ML engineers did *before* TonY (paper §1).
//!
//! A pool of unmanaged machines and a launch script that copies the
//! program to hand-picked hosts and starts tasks with **no resource
//! isolation, no admission control, no monitoring, and no restarts**.
//! Used by the C1 contention bench and `examples/contention.rs` to
//! quantify §1's four challenges against TonY's managed path.
//!
//! Failure model (matching the paper's complaints):
//! - Tasks land on user-chosen (here: round-robin/random) hosts without
//!   checking capacity; if a host's *physical* memory is exceeded by its
//!   co-resident tasks, the overcommitted task OOMs (probabilistically,
//!   proportional to overcommit) — "jobs may fail with out-of-memory
//!   exceptions or errors allocating GPUs".
//! - Each host's config must be assembled by hand; with `n` hosts the
//!   chance of a copy-paste error grows (modeled with a per-host error
//!   rate), yielding mis-configured jobs that waste their runtime before
//!   failing.
//! - A failed task is NOT restarted; the job is lost.

use crate::util::SplitMix64;
use crate::yarn::Resource;

/// One unmanaged host.
#[derive(Debug, Clone)]
pub struct AdhocHost {
    pub capacity: Resource,
    pub committed: Resource,
}

/// A task the user wants to run somewhere.
#[derive(Debug, Clone)]
pub struct AdhocTask {
    pub job: u32,
    pub need: Resource,
    /// Runtime if all goes well, ms (virtual).
    pub runtime_ms: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdhocOutcome {
    Succeeded,
    OomKilled,
    Misconfigured,
}

#[derive(Debug, Clone)]
pub struct AdhocJobResult {
    pub job: u32,
    pub outcome: AdhocOutcome,
    /// Virtual completion time (ms since pool start), if it ran at all.
    pub finished_at_ms: u64,
}

/// Simulation parameters for the ad-hoc pool.
#[derive(Debug, Clone)]
pub struct AdhocParams {
    /// Probability a hand-copied per-host config is wrong.
    pub per_host_config_error: f64,
    pub seed: u64,
}

impl Default for AdhocParams {
    fn default() -> Self {
        AdhocParams { per_host_config_error: 0.02, seed: 0 }
    }
}

/// Run a set of jobs (each a list of tasks) on an unmanaged pool and
/// report per-job outcomes.  Virtual time: all tasks start immediately
/// (nobody queues in the ad-hoc world — that is exactly the problem).
pub fn run_adhoc_pool(
    hosts: &[Resource],
    jobs: &[Vec<AdhocTask>],
    params: &AdhocParams,
) -> Vec<AdhocJobResult> {
    let mut rng = SplitMix64::new(params.seed);
    let mut pool: Vec<AdhocHost> = hosts
        .iter()
        .map(|c| AdhocHost { capacity: *c, committed: Resource::ZERO })
        .collect();

    // Placement: users pick hosts by hand; model as random choice.
    // Every task gets placed (no admission control).
    struct Placed {
        job: u32,
        host: usize,
        need: Resource,
        runtime_ms: u64,
        misconfigured: bool,
    }
    let mut placed = Vec::new();
    for tasks in jobs {
        for t in tasks {
            let host = rng.next_below(pool.len() as u64) as usize;
            pool[host].committed += t.need;
            let misconfigured = rng.chance(params.per_host_config_error);
            placed.push(Placed {
                job: t.job,
                host,
                need: t.need,
                runtime_ms: t.runtime_ms,
                misconfigured,
            });
        }
    }

    // OOM: on each host, if commitment exceeds capacity, tasks die with
    // probability proportional to the overcommit fraction (the kernel's
    // OOM killer takes someone).
    let mut task_outcomes: Vec<AdhocOutcome> = Vec::with_capacity(placed.len());
    for p in &placed {
        if p.misconfigured {
            task_outcomes.push(AdhocOutcome::Misconfigured);
            continue;
        }
        let h = &pool[p.host];
        let over = h.committed.memory_mb as f64 / h.capacity.memory_mb.max(1) as f64;
        if over > 1.0 {
            // Overcommit ratio 1.5 -> ~1/3 of memory demand unservable.
            let p_oom = ((over - 1.0) / over).clamp(0.0, 1.0);
            // Bigger tasks are likelier victims.
            let weight =
                p.need.memory_mb as f64 / h.committed.memory_mb.max(1) as f64;
            if rng.chance((p_oom * (0.5 + weight)).min(0.95)) {
                task_outcomes.push(AdhocOutcome::OomKilled);
                continue;
            }
        }
        task_outcomes.push(AdhocOutcome::Succeeded);
    }

    // Job outcome = all its tasks succeeded (no restarts in ad-hoc land).
    let n_jobs = jobs.len() as u32;
    (0..n_jobs)
        .map(|job| {
            let mut outcome = AdhocOutcome::Succeeded;
            let mut finish = 0u64;
            for (p, o) in placed.iter().zip(&task_outcomes) {
                if p.job != job {
                    continue;
                }
                finish = finish.max(p.runtime_ms);
                match o {
                    AdhocOutcome::Succeeded => {}
                    bad => {
                        outcome = *bad;
                    }
                }
            }
            AdhocJobResult { job, outcome, finished_at_ms: finish }
        })
        .collect()
}

/// Managed (TonY/YARN) counterpart in the same virtual-time model:
/// admission-controlled placement — jobs queue until capacity frees, no
/// OOM (containers are isolated), no config errors (central spec).
/// Returns per-job finish times; all jobs succeed.
pub fn run_managed_pool(hosts: &[Resource], jobs: &[Vec<AdhocTask>]) -> Vec<AdhocJobResult> {
    #[derive(Clone)]
    struct Running {
        host: usize,
        need: Resource,
        done_at: u64,
        job: u32,
    }
    let mut free: Vec<Resource> = hosts.to_vec();
    let mut running: Vec<Running> = Vec::new();
    let mut queue: Vec<(u32, AdhocTask)> = jobs
        .iter()
        .flat_map(|tasks| tasks.iter().map(|t| (t.job, t.clone())))
        .collect();
    let mut now = 0u64;
    let mut finished_at = vec![0u64; jobs.len()];

    while !queue.is_empty() || !running.is_empty() {
        // Start everything that fits (first-fit).
        let mut i = 0;
        while i < queue.len() {
            let (job, t) = &queue[i];
            match free.iter().position(|f| f.fits(&t.need)) {
                Some(h) => {
                    free[h] -= t.need;
                    running.push(Running {
                        host: h,
                        need: t.need,
                        done_at: now + t.runtime_ms,
                        job: *job,
                    });
                    queue.remove(i);
                }
                None => i += 1,
            }
        }
        // Advance virtual time to the next completion.
        let Some(next) = running.iter().map(|r| r.done_at).min() else {
            if queue.is_empty() {
                break;
            }
            // Nothing runs and nothing fits: impossible jobs. Guard.
            break;
        };
        now = next;
        let mut j = 0;
        while j < running.len() {
            if running[j].done_at <= now {
                let r = running.remove(j);
                free[r.host] += r.need;
                finished_at[r.job as usize] = finished_at[r.job as usize].max(now);
            } else {
                j += 1;
            }
        }
    }
    (0..jobs.len() as u32)
        .map(|job| AdhocJobResult {
            job,
            outcome: AdhocOutcome::Succeeded,
            finished_at_ms: finished_at[job as usize],
        })
        .collect()
}

/// Workload generator: `n_jobs` identical PS/worker-style jobs.
pub fn synthetic_jobs(n_jobs: u32, tasks_per_job: u32, mem_mb: u64, runtime_ms: u64) -> Vec<Vec<AdhocTask>> {
    (0..n_jobs)
        .map(|job| {
            (0..tasks_per_job)
                .map(|_| AdhocTask {
                    job,
                    need: Resource::mem_cores(mem_mb, 1),
                    runtime_ms,
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn undersubscribed_adhoc_pool_mostly_succeeds() {
        let hosts = vec![Resource::mem_cores(16384, 16); 8];
        let jobs = synthetic_jobs(4, 2, 1024, 1000);
        let params = AdhocParams { per_host_config_error: 0.0, seed: 1 };
        let results = run_adhoc_pool(&hosts, &jobs, &params);
        assert!(results.iter().all(|r| r.outcome == AdhocOutcome::Succeeded));
    }

    #[test]
    fn oversubscribed_adhoc_pool_looses_jobs() {
        let hosts = vec![Resource::mem_cores(4096, 8); 2];
        // 16 jobs x 2 tasks x 2 GiB = 64 GiB onto 8 GiB of hosts.
        let jobs = synthetic_jobs(16, 2, 2048, 1000);
        let params = AdhocParams { per_host_config_error: 0.0, seed: 2 };
        let results = run_adhoc_pool(&hosts, &jobs, &params);
        let failed = results.iter().filter(|r| r.outcome != AdhocOutcome::Succeeded).count();
        assert!(failed > 8, "expected heavy OOM carnage, got {failed}/16 failures");
    }

    #[test]
    fn managed_pool_queues_and_finishes_everything() {
        let hosts = vec![Resource::mem_cores(4096, 8); 2];
        let jobs = synthetic_jobs(16, 2, 2048, 1000);
        let results = run_managed_pool(&hosts, &jobs);
        assert!(results.iter().all(|r| r.outcome == AdhocOutcome::Succeeded));
        // With 8 GiB total and 4 GiB per job, at most 2 jobs run at once:
        // makespan must reflect queuing (≥ 8 waves x 1000 ms).
        let makespan = results.iter().map(|r| r.finished_at_ms).max().unwrap();
        assert!(makespan >= 8000, "makespan {makespan}");
    }

    #[test]
    fn config_errors_scale_with_hosts() {
        let hosts = vec![Resource::mem_cores(65536, 64); 16];
        let jobs = synthetic_jobs(50, 8, 512, 100);
        let params = AdhocParams { per_host_config_error: 0.05, seed: 3 };
        let results = run_adhoc_pool(&hosts, &jobs, &params);
        let misconfigured = results
            .iter()
            .filter(|r| r.outcome == AdhocOutcome::Misconfigured)
            .count();
        assert!(misconfigured > 5, "8 tasks x 5% per-host error should bite: {misconfigured}");
    }
}
