//! Training data pipeline: synthetic corpus generation, byte-level
//! tokenization, and deterministic sharded batching.
//!
//! The paper's jobs read petabytes from HDFS; the substitution (DESIGN.md
//! §1) is a generated corpus with enough structure that the LM's loss
//! curve is meaningful: a Markov-ish "pseudo-English" stream built from a
//! fixed word list, so there are learnable bigram/word statistics.  Every
//! batch is a pure function of `(seed, worker_index, step)` — workers
//! shard by construction and restarts replay the exact stream, which is
//! what makes checkpoint-restore exactly resumable.

use crate::util::SplitMix64;

/// Fixed vocabulary of "words" (byte strings) for the synthetic corpus.
const WORDS: &[&str] = &[
    "the", "model", "gradient", "tensor", "train", "loss", "batch", "layer",
    "deep", "data", "learning", "scale", "cluster", "worker", "server",
    "adam", "step", "epoch", "token", "linear", "attention", "head",
    "forward", "backward", "update", "schedule", "checkpoint", "restore",
];

/// Generates token sequences over a byte vocabulary (0..vocab).
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub vocab: usize,
    seed: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        assert!(vocab >= 128, "byte-level corpus needs vocab >= 128");
        SyntheticCorpus { vocab, seed }
    }

    /// One training sequence of `len` tokens for (worker, step, row).
    /// Sentences are word sequences joined by spaces with a period+newline
    /// terminator — enough structure for next-byte prediction to learn.
    pub fn sequence(&self, worker: u32, step: u64, row: u32, len: usize) -> Vec<i32> {
        let mut rng = SplitMix64::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ step.wrapping_mul(0xBF58_476D_1CE4_E5B9)
                ^ (row as u64).wrapping_mul(0x94D0_49BB_1331_11EB),
        );
        let mut bytes: Vec<u8> = Vec::with_capacity(len + 16);
        while bytes.len() < len {
            // Sentence of 3..8 words; word choice is zipf-ish (prefer the
            // head of the list) so frequencies are learnable.
            let n_words = rng.range_usize(3, 8);
            for i in 0..n_words {
                let z = rng.next_f64() * rng.next_f64(); // squared-uniform ~ head-heavy
                let w = WORDS[(z * WORDS.len() as f64) as usize % WORDS.len()];
                bytes.extend_from_slice(w.as_bytes());
                if i + 1 < n_words {
                    bytes.push(b' ');
                }
            }
            bytes.extend_from_slice(b".\n");
        }
        bytes.truncate(len);
        bytes.iter().map(|b| (*b as usize % self.vocab) as i32).collect()
    }

    /// A `[batch, seq_len + 1]` token block (inputs + shifted targets).
    pub fn batch(&self, worker: u32, step: u64, batch: usize, seq_len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq_len + 1));
        for row in 0..batch {
            out.extend(self.sequence(worker, step, row as u32, seq_len + 1));
        }
        out
    }
}

/// Tokenizer utilities (byte-level; identity-ish but bounded by vocab).
pub fn encode_bytes(text: &str, vocab: usize) -> Vec<i32> {
    text.bytes().map(|b| (b as usize % vocab) as i32).collect()
}

pub fn decode_bytes(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|t| {
            let b = (*t).clamp(0, 255) as u8;
            if b.is_ascii_graphic() || b == b' ' || b == b'\n' {
                b as char
            } else {
                '?'
            }
        })
        .collect()
}

/// Batches from a real text file, sharded by worker (round-robin rows) —
/// used by examples that train on an actual corpus file.
#[derive(Debug, Clone)]
pub struct FileCorpus {
    tokens: Vec<i32>,
    pub vocab: usize,
}

impl FileCorpus {
    pub fn from_text(text: &str, vocab: usize) -> FileCorpus {
        FileCorpus { tokens: encode_bytes(text, vocab), vocab }
    }

    pub fn load(path: &std::path::Path, vocab: usize) -> anyhow::Result<FileCorpus> {
        Ok(Self::from_text(&std::fs::read_to_string(path)?, vocab))
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Deterministic `[batch, seq_len+1]` block for (worker, step).
    pub fn batch(&self, worker: u32, step: u64, batch: usize, seq_len: usize) -> Vec<i32> {
        let need = seq_len + 1;
        assert!(self.tokens.len() > need, "corpus shorter than one sequence");
        let mut rng = SplitMix64::new(
            0xC0FFEE ^ (worker as u64) << 32 ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut out = Vec::with_capacity(batch * need);
        for _ in 0..batch {
            let start = rng.range_usize(0, self.tokens.len() - need - 1);
            out.extend_from_slice(&self.tokens[start..start + need]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic() {
        let c = SyntheticCorpus::new(256, 7);
        assert_eq!(c.batch(0, 5, 4, 32), c.batch(0, 5, 4, 32));
        assert_ne!(c.batch(0, 5, 4, 32), c.batch(1, 5, 4, 32), "workers shard");
        assert_ne!(c.batch(0, 5, 4, 32), c.batch(0, 6, 4, 32), "steps differ");
    }

    #[test]
    fn batch_shape_and_range() {
        let c = SyntheticCorpus::new(256, 0);
        let b = c.batch(2, 9, 3, 16);
        assert_eq!(b.len(), 3 * 17);
        assert!(b.iter().all(|t| (0..256).contains(t)));
    }

    #[test]
    fn corpus_has_structure() {
        // Space must be among the most frequent bytes (word separators).
        let c = SyntheticCorpus::new(256, 1);
        let seq = c.sequence(0, 0, 0, 4096);
        let spaces = seq.iter().filter(|&&t| t == b' ' as i32).count();
        assert!(spaces > 200, "expected many spaces, got {spaces}");
    }

    #[test]
    fn encode_decode() {
        let text = "the model trains.\n";
        let toks = encode_bytes(text, 256);
        assert_eq!(decode_bytes(&toks), text);
    }

    #[test]
    fn file_corpus_batches() {
        let text = "hello world ".repeat(100);
        let fc = FileCorpus::from_text(&text, 256);
        let b = fc.batch(0, 0, 2, 8);
        assert_eq!(b.len(), 2 * 9);
        assert_eq!(fc.batch(1, 3, 2, 8), fc.batch(1, 3, 2, 8));
    }
}
