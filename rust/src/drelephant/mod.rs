//! Dr. Elephant-style analysis (paper §3 / future work): aggregate the
//! per-task metrics the TaskExecutors collected and run tuning heuristics
//! that "suggest new settings for the ML jobs that would improve
//! performance and resource utilization".
//!
//! Heuristics implemented (each returns severity + a concrete suggestion):
//! - **Memory over-provisioning**: requested container memory ≫ observed
//!   working set.
//! - **Straggler detection**: one worker's step time ≫ the median.
//! - **PS imbalance**: one PS shard applies far more updates / bytes than
//!   the others (hot chunk distribution).
//! - **Too-frequent checkpoints**: checkpoint interval below step time ×
//!   threshold (training stalls on I/O).
//! - **Low MXU/arith utilization**: achieved FLOP/s far below the preset's
//!   roofline estimate (batch too small, sync barrier dominated).

use crate::framework::TaskMetrics;
use crate::json::Json;
use crate::runtime::ArtifactMeta;
use crate::tonyconf::JobSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    None,
    Low,
    Moderate,
    Severe,
}

#[derive(Debug, Clone)]
pub struct Finding {
    pub heuristic: &'static str,
    pub severity: Severity,
    pub task: String,
    pub detail: String,
    pub suggestion: String,
}

impl Finding {
    /// JSON shape served by the portal's `/findings` and the gateway's
    /// per-job status for running jobs.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("heuristic", self.heuristic);
        j.set("severity", format!("{:?}", self.severity));
        j.set("task", self.task.as_str());
        j.set("detail", self.detail.as_str());
        j.set("suggestion", self.suggestion.as_str());
        j
    }
}

/// Render a finding list as a JSON array.
pub fn findings_json(findings: &[Finding]) -> Json {
    Json::Arr(findings.iter().map(Finding::to_json).collect())
}

/// Everything the analyzer consumes about one finished (or running) job.
#[derive(Debug, Clone, Default)]
pub struct JobTelemetry {
    /// (task id string, metrics) for every task.
    pub tasks: Vec<(String, TaskMetrics)>,
    /// Requested memory per task type, MB.
    pub requested_mem_mb: Vec<(String, u64)>,
    pub checkpoint_every: u64,
    /// FLOPs per step (from ArtifactMeta) for utilization accounting.
    pub flops_per_step: f64,
}

impl JobTelemetry {
    pub fn from_job(job: &JobSpec, meta: &ArtifactMeta, tasks: Vec<(String, TaskMetrics)>) -> Self {
        JobTelemetry {
            tasks,
            requested_mem_mb: job
                .task_types
                .iter()
                .map(|t| (t.name.clone(), t.resource.memory_mb))
                .collect(),
            checkpoint_every: job.train.checkpoint_every,
            flops_per_step: meta.flops_per_step(),
        }
    }

    /// Telemetry from a *running* job's latest heartbeat snapshot — the
    /// streaming path (no `ArtifactMeta` mid-run, so the utilization
    /// heuristic is skipped via `flops_per_step = 0`).
    pub fn from_live(job: &JobSpec, tasks: Vec<(String, TaskMetrics)>) -> Self {
        JobTelemetry {
            tasks,
            requested_mem_mb: job
                .task_types
                .iter()
                .map(|t| (t.name.clone(), t.resource.memory_mb))
                .collect(),
            checkpoint_every: job.train.checkpoint_every,
            flops_per_step: 0.0,
        }
    }
}

/// Run the heuristics *streaming* against a live AM: stragglers and
/// memory-pressure tasks are flagged from the latest heartbeat snapshot
/// while the job is still running, instead of only post-hoc (the portal
/// serves this on `/findings`; the gateway embeds it in job status).
pub fn analyze_live(state: &crate::am::AmState) -> Vec<Finding> {
    analyze(&JobTelemetry::from_live(state.job_spec(), state.task_metrics()))
}

/// Assumed single-node peak for utilization heuristics (CPU testbed).
/// Deliberately conservative; see EXPERIMENTS.md §Perf for calibration.
pub const PEAK_FLOPS: f64 = 5.0e10;

pub fn analyze(t: &JobTelemetry) -> Vec<Finding> {
    let mut findings = Vec::new();
    memory_heuristic(t, &mut findings);
    straggler_heuristic(t, &mut findings);
    ps_imbalance_heuristic(t, &mut findings);
    checkpoint_heuristic(t, &mut findings);
    utilization_heuristic(t, &mut findings);
    findings
}

fn task_type_of(id: &str) -> &str {
    id.split(':').next().unwrap_or(id)
}

fn memory_heuristic(t: &JobTelemetry, out: &mut Vec<Finding>) {
    for (task, m) in &t.tasks {
        let ty = task_type_of(task);
        let Some((_, req)) = t.requested_mem_mb.iter().find(|(n, _)| n == ty) else {
            continue;
        };
        if *req == 0 || m.mem_used_mb == 0 {
            continue;
        }
        let ratio = *req as f64 / m.mem_used_mb.max(1) as f64;
        let severity = if ratio >= 16.0 {
            Severity::Severe
        } else if ratio >= 8.0 {
            Severity::Moderate
        } else if ratio >= 4.0 {
            Severity::Low
        } else {
            Severity::None
        };
        if severity > Severity::None {
            let suggest = (m.mem_used_mb * 2).max(256);
            out.push(Finding {
                heuristic: "memory-over-provisioning",
                severity,
                task: task.clone(),
                detail: format!("requested {req} MB, observed working set {} MB", m.mem_used_mb),
                suggestion: format!("set tony.{ty}.memory to ~{suggest}m (2x observed)"),
            });
        }
    }
}

fn straggler_heuristic(t: &JobTelemetry, out: &mut Vec<Finding>) {
    let mut worker_times: Vec<(&str, f64)> = t
        .tasks
        .iter()
        .filter(|(id, m)| task_type_of(id) == "worker" && m.step_ms_avg > 0.0)
        .map(|(id, m)| (id.as_str(), m.step_ms_avg))
        .collect();
    if worker_times.len() < 2 {
        return;
    }
    worker_times.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let median = worker_times[worker_times.len() / 2].1;
    for (id, ms) in &worker_times {
        let ratio = ms / median.max(1e-9);
        let severity = if ratio >= 3.0 {
            Severity::Severe
        } else if ratio >= 2.0 {
            Severity::Moderate
        } else if ratio >= 1.5 {
            Severity::Low
        } else {
            Severity::None
        };
        if severity > Severity::None {
            out.push(Finding {
                heuristic: "straggler",
                severity,
                task: id.to_string(),
                detail: format!("step time {ms:.1} ms vs median {median:.1} ms"),
                suggestion: "check the node's co-tenants or use a node label to avoid it; \
                             in sync mode a straggler gates every step"
                    .to_string(),
            });
        }
    }
}

fn ps_imbalance_heuristic(t: &JobTelemetry, out: &mut Vec<Finding>) {
    let ps: Vec<(&str, u64)> = t
        .tasks
        .iter()
        .filter(|(id, _)| task_type_of(id) == "ps")
        .map(|(id, m)| (id.as_str(), m.updates_applied))
        .collect();
    if ps.len() < 2 {
        return;
    }
    let max = ps.iter().map(|(_, u)| *u).max().unwrap_or(0);
    let min = ps.iter().map(|(_, u)| *u).min().unwrap_or(0);
    if max == 0 {
        return;
    }
    let ratio = max as f64 / min.max(1) as f64;
    let severity = if ratio >= 4.0 {
        Severity::Severe
    } else if ratio >= 2.0 {
        Severity::Moderate
    } else {
        Severity::None
    };
    if severity > Severity::None {
        out.push(Finding {
            heuristic: "ps-imbalance",
            severity,
            task: "ps:*".to_string(),
            detail: format!("update counts range {min}..{max} across shards"),
            suggestion: "chunk count should be >= several x n_ps for round-robin balance; \
                         lower chunk_len at AOT time or reduce tony.ps.instances"
                .to_string(),
        });
    }
}

fn checkpoint_heuristic(t: &JobTelemetry, out: &mut Vec<Finding>) {
    if t.checkpoint_every == 0 {
        out.push(Finding {
            heuristic: "checkpointing-disabled",
            severity: Severity::Moderate,
            task: "worker:0".to_string(),
            detail: "checkpointing is off".to_string(),
            suggestion: "set tony.train.checkpoint-every > 0 or a task failure restarts \
                         training from step 0"
                .to_string(),
        });
        return;
    }
    if t.checkpoint_every <= 2 {
        out.push(Finding {
            heuristic: "checkpoint-too-frequent",
            severity: Severity::Low,
            task: "worker:0".to_string(),
            detail: format!("checkpoint every {} steps", t.checkpoint_every),
            suggestion: "checkpointing each step serializes the full parameter vector; \
                         raise tony.train.checkpoint-every"
                .to_string(),
        });
    }
}

fn utilization_heuristic(t: &JobTelemetry, out: &mut Vec<Finding>) {
    for (task, m) in &t.tasks {
        if task_type_of(task) != "worker" || m.step_ms_avg <= 0.0 || t.flops_per_step <= 0.0 {
            continue;
        }
        let achieved = t.flops_per_step / (m.step_ms_avg / 1e3);
        let util = achieved / PEAK_FLOPS;
        if util < 0.05 {
            out.push(Finding {
                heuristic: "low-utilization",
                severity: Severity::Low,
                task: task.clone(),
                detail: format!(
                    "achieved ~{:.2} GFLOP/s ({:.1}% of assumed peak)",
                    achieved / 1e9,
                    util * 100.0
                ),
                suggestion: "increase batch size at AOT time, or use async mode if the \
                             sync barrier dominates"
                    .to_string(),
            });
        }
    }
}

/// Render findings as the report table the paper's §3 envisions.
pub fn render_report(findings: &[Finding]) -> String {
    if findings.is_empty() {
        return "Dr. Elephant: no findings — job looks healthy.\n".to_string();
    }
    let mut out = String::from(
        "Dr. Elephant report\nseverity  heuristic                    task        detail\n",
    );
    for f in findings {
        out.push_str(&format!(
            "{:<9} {:<28} {:<11} {}\n          -> {}\n",
            format!("{:?}", f.severity),
            f.heuristic,
            f.task,
            f.detail,
            f.suggestion
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wm(step_ms: f64, mem: u64) -> TaskMetrics {
        TaskMetrics { step_ms_avg: step_ms, mem_used_mb: mem, step: 100, ..Default::default() }
    }

    #[test]
    fn over_provisioned_memory_flagged() {
        let t = JobTelemetry {
            tasks: vec![("worker:0".into(), wm(10.0, 64))],
            requested_mem_mb: vec![("worker".into(), 4096)],
            checkpoint_every: 10,
            flops_per_step: 1e9,
        };
        let f = analyze(&t);
        let mem = f.iter().find(|f| f.heuristic == "memory-over-provisioning").unwrap();
        assert_eq!(mem.severity, Severity::Severe);
        assert!(mem.suggestion.contains("tony.worker.memory"));
    }

    #[test]
    fn straggler_flagged() {
        let t = JobTelemetry {
            tasks: vec![
                ("worker:0".into(), wm(10.0, 0)),
                ("worker:1".into(), wm(11.0, 0)),
                ("worker:2".into(), wm(40.0, 0)),
            ],
            requested_mem_mb: vec![],
            checkpoint_every: 10,
            flops_per_step: 0.0,
        };
        let f = analyze(&t);
        let s = f.iter().find(|f| f.heuristic == "straggler").unwrap();
        assert_eq!(s.task, "worker:2");
        assert_eq!(s.severity, Severity::Severe);
    }

    #[test]
    fn ps_imbalance_flagged() {
        let mk = |u: u64| TaskMetrics { updates_applied: u, ..Default::default() };
        let t = JobTelemetry {
            tasks: vec![("ps:0".into(), mk(100)), ("ps:1".into(), mk(10))],
            requested_mem_mb: vec![],
            checkpoint_every: 10,
            flops_per_step: 0.0,
        };
        let f = analyze(&t);
        assert!(f.iter().any(|f| f.heuristic == "ps-imbalance"));
    }

    #[test]
    fn checkpoint_heuristics() {
        let base = JobTelemetry { checkpoint_every: 0, ..Default::default() };
        assert!(analyze(&base).iter().any(|f| f.heuristic == "checkpointing-disabled"));
        let freq = JobTelemetry { checkpoint_every: 1, ..Default::default() };
        assert!(analyze(&freq).iter().any(|f| f.heuristic == "checkpoint-too-frequent"));
        let fine = JobTelemetry { checkpoint_every: 25, ..Default::default() };
        assert!(!analyze(&fine).iter().any(|f| f.heuristic.starts_with("checkpoint")));
    }

    #[test]
    fn healthy_job_clean_report() {
        let t = JobTelemetry {
            tasks: vec![
                ("worker:0".into(), wm(10.0, 512)),
                ("worker:1".into(), wm(10.5, 512)),
            ],
            requested_mem_mb: vec![("worker".into(), 1024)],
            checkpoint_every: 25,
            flops_per_step: 5e10, // keeps utilization above threshold
        };
        let f = analyze(&t);
        assert!(f.is_empty(), "{f:?}");
        assert!(render_report(&f).contains("healthy"));
    }
}
