//! Hand-rolled binary (de)serialization for RPC payloads.
//!
//! Little-endian, varint-free (fixed-width ints keep the hot gradient
//! push/pull path branchless and allow bulk `f32` slice copies).  The
//! `Wire` trait plays the role serde would in an online build; the
//! property tests in `rust/tests/prop_wire.rs` fuzz round-trips.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError(pub String);

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "wire error: {}", self.0)
    }
}

impl std::error::Error for WireError {}

/// Append-only encoder over a reusable byte buffer.
#[derive(Debug, Default)]
pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Writer {
        Writer { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Bulk f32 slice: single memcpy on little-endian targets.
    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        if cfg!(target_endian = "little") {
            // SAFETY: f32 and [u8; 4] have the same layout; LE matches wire.
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            self.buf.extend_from_slice(bytes);
        } else {
            for x in v {
                self.f32(*x);
            }
        }
    }

    pub fn i32_slice(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        if cfg!(target_endian = "little") {
            let bytes = unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            };
            self.buf.extend_from_slice(bytes);
        } else {
            for x in v {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Cursor-based decoder.
pub struct Reader<'a> {
    pub buf: &'a [u8],
    pub pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError(format!(
                "short read: need {} bytes at {}, have {}",
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, WireError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], WireError> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let b = self.bytes()?;
        std::str::from_utf8(b)
            .map(|s| s.to_string())
            .map_err(|_| WireError("invalid utf-8 in string".into()))
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| WireError("overflow".into()))?)?;
        let mut out = vec![0f32; n];
        if cfg!(target_endian = "little") {
            // SAFETY: same layout, LE wire format.
            unsafe {
                std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
            }
        } else {
            for (i, c) in raw.chunks_exact(4).enumerate() {
                out[i] = f32::from_le_bytes(c.try_into().unwrap());
            }
        }
        Ok(out)
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| WireError("overflow".into()))?)?;
        let mut out = vec![0i32; n];
        if cfg!(target_endian = "little") {
            unsafe {
                std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
            }
        } else {
            for (i, c) in raw.chunks_exact(4).enumerate() {
                out[i] = i32::from_le_bytes(c.try_into().unwrap());
            }
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// A type with a canonical wire encoding.
pub trait Wire: Sized {
    fn encode(&self, w: &mut Writer);
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError>;

    fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.buf
    }

    fn from_bytes(b: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::new(b);
        let v = Self::decode(&mut r)?;
        if r.remaining() != 0 {
            return Err(WireError(format!("{} trailing bytes", r.remaining())));
        }
        Ok(v)
    }
}

impl Wire for String {
    fn encode(&self, w: &mut Writer) {
        w.str(self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.str()
    }
}

impl Wire for u64 {
    fn encode(&self, w: &mut Writer) {
        w.u64(*self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u64()
    }
}

impl Wire for u32 {
    fn encode(&self, w: &mut Writer) {
        w.u32(*self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u32()
    }
}

impl Wire for bool {
    fn encode(&self, w: &mut Writer) {
        w.bool(*self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.bool()
    }
}

impl Wire for f32 {
    fn encode(&self, w: &mut Writer) {
        w.f32(*self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.f32()
    }
}

impl Wire for f64 {
    fn encode(&self, w: &mut Writer) {
        w.f64(*self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.f64()
    }
}

impl Wire for Vec<f32> {
    fn encode(&self, w: &mut Writer) {
        w.f32_slice(self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.f32_vec()
    }
}

impl Wire for Vec<i32> {
    fn encode(&self, w: &mut Writer) {
        w.i32_slice(self);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.i32_vec()
    }
}

impl<T: Wire> Wire for Option<T> {
    fn encode(&self, w: &mut Writer) {
        match self {
            None => w.u8(0),
            Some(v) => {
                w.u8(1);
                v.encode(w);
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            t => Err(WireError(format!("bad option tag {t}"))),
        }
    }
}

// Rust lacks specialization on stable, so a blanket `impl Wire for Vec<T>`
// would conflict with the bulk-memcpy Vec<f32>/Vec<i32> impls above.
// Generate element-wise Vec impls for the remaining payload types instead.
macro_rules! wire_vec {
    ($($t:ty),*) => {$(
        impl Wire for Vec<$t> {
            fn encode(&self, w: &mut Writer) {
                w.u32(self.len() as u32);
                for v in self {
                    v.encode(w);
                }
            }

            fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
                let n = r.u32()? as usize;
                let mut out = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    out.push(<$t>::decode(r)?);
                }
                Ok(out)
            }
        }
    )*};
}


wire_vec!(String, u64, u32, f64);

impl<K: Wire + Ord, V: Wire> Wire for BTreeMap<K, V> {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.len() as u32);
        for (k, v) in self {
            k.encode(w);
            v.encode(w);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let n = r.u32()? as usize;
        let mut out = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            out.insert(k, v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX);
        w.f32(1.5);
        w.f64(-2.25);
        w.bool(true);
        w.str("héllo");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert!(r.bool().unwrap());
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn f32_bulk_round_trip() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 17.0).collect();
        let b = xs.to_bytes();
        assert_eq!(Vec::<f32>::from_bytes(&b).unwrap(), xs);
    }

    #[test]
    fn short_read_is_error() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
        // Truncated length-prefixed payload:
        let mut w = Writer::new();
        w.f32_slice(&[1.0, 2.0]);
        let b = &w.buf[..w.buf.len() - 1];
        assert!(Vec::<f32>::from_bytes(b).is_err());
    }

    #[test]
    fn trailing_bytes_is_error() {
        let mut b = 5u64.to_bytes();
        b.push(0);
        assert!(u64::from_bytes(&b).is_err());
    }

    #[test]
    fn option_and_map() {
        let v: Option<String> = Some("x".into());
        assert_eq!(Option::<String>::from_bytes(&v.to_bytes()).unwrap(), v);
        let n: Option<String> = None;
        assert_eq!(Option::<String>::from_bytes(&n.to_bytes()).unwrap(), n);
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), 1u64);
        m.insert("b".to_string(), 2u64);
        assert_eq!(BTreeMap::<String, u64>::from_bytes(&m.to_bytes()).unwrap(), m);
    }

    #[test]
    fn vec_of_strings() {
        let v = vec!["a".to_string(), "bb".to_string(), String::new()];
        assert_eq!(Vec::<String>::from_bytes(&v.to_bytes()).unwrap(), v);
    }
}
