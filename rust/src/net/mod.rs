//! Networking substrate: wire codec + length-prefixed TCP RPC.
//!
//! The paper's tasks talk over two protocols: (1) TaskExecutor <-> AM
//! registration/heartbeat RPC, and (2) the ML framework's own distributed
//! protocol between workers and parameter servers (§2.2: "they will
//! communicate and coordinate with one another via the ML framework's
//! distributed protocol").  Both run over this module: a simple
//! request/response RPC with a 4-byte length prefix, a method id, and
//! hand-rolled binary serialization (`Wire`).  Thread-per-connection on
//! `std::net` — no tokio in this offline build.

pub mod rpc;
pub mod wire;

pub use rpc::{RpcClient, RpcError, RpcHandler, RpcServer};
pub use wire::{Reader, Wire, WireError, Writer};
