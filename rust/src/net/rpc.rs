//! Length-prefixed request/response RPC over TCP.
//!
//! Frame layout (all little-endian):
//!
//! ```text
//!   request:  u32 len | u64 call_id | u16 method | payload bytes
//!   response: u32 len | u64 call_id | u8 status  | payload-or-error bytes
//! ```
//!
//! The server is thread-per-connection (`std::net`); handlers are
//! `Fn(method, payload) -> Result<Vec<u8>, String>` behind an `Arc`, so
//! one handler instance serves all connections — exactly how the TonY AM
//! serves TaskExecutor registrations and how PS shards serve workers.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::wire::WireError;
use crate::util::HostPort;

const MAX_FRAME: u32 = 1 << 30; // 1 GiB sanity bound

#[derive(Debug)]
pub enum RpcError {
    Io(std::io::Error),
    Wire(WireError),
    /// The remote handler returned an application-level error.
    Remote(String),
    Closed,
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::Io(e) => write!(f, "rpc io error: {e}"),
            RpcError::Wire(e) => write!(f, "rpc {e}"),
            RpcError::Remote(m) => write!(f, "rpc remote error: {m}"),
            RpcError::Closed => write!(f, "rpc connection closed"),
        }
    }
}

impl std::error::Error for RpcError {}

impl From<std::io::Error> for RpcError {
    fn from(e: std::io::Error) -> Self {
        RpcError::Io(e)
    }
}

impl From<WireError> for RpcError {
    fn from(e: WireError) -> Self {
        RpcError::Wire(e)
    }
}

/// Server-side dispatch: `(method, request_payload) -> payload | error`.
pub trait RpcHandler: Send + Sync + 'static {
    fn handle(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, String>;
}

impl<F> RpcHandler for F
where
    F: Fn(u16, &[u8]) -> Result<Vec<u8>, String> + Send + Sync + 'static,
{
    fn handle(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, String> {
        self(method, payload)
    }
}

fn read_exact_frame(stream: &mut TcpStream) -> Result<Option<Vec<u8>>, RpcError> {
    let mut len_buf = [0u8; 4];
    match stream.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e)
            if e.kind() == std::io::ErrorKind::UnexpectedEof
                || e.kind() == std::io::ErrorKind::ConnectionReset =>
        {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(RpcError::Wire(WireError(format!("frame too large: {len}"))));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(Some(buf))
}

fn write_frame_buf(
    stream: &mut TcpStream,
    scratch: &mut Vec<u8>,
    head: &[u8],
    payload: &[u8],
) -> Result<(), RpcError> {
    // One write_all over a reused buffer: a single syscall, atomic framing,
    // and no per-message allocation on the hot gradient push/pull path
    // (§Perf L3 pass 1: -1 alloc/free of up to payload-size per message).
    scratch.clear();
    scratch.reserve(4 + head.len() + payload.len());
    scratch.extend_from_slice(&((head.len() + payload.len()) as u32).to_le_bytes());
    scratch.extend_from_slice(head);
    scratch.extend_from_slice(payload);
    stream.write_all(scratch)?;
    Ok(())
}

/// A running RPC server; drop or call `shutdown()` to stop accepting.
pub struct RpcServer {
    addr: HostPort,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl RpcServer {
    /// Bind on 127.0.0.1 with an OS-assigned port and start serving.
    pub fn serve(handler: Arc<dyn RpcHandler>) -> Result<RpcServer, RpcError> {
        Self::serve_on("127.0.0.1:0", handler)
    }

    pub fn serve_on(bind: &str, handler: Arc<dyn RpcHandler>) -> Result<RpcServer, RpcError> {
        let listener = TcpListener::bind(bind)?;
        let addr = HostPort::from_addr(listener.local_addr()?);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        // Accept loop wakes up periodically to observe the stop flag.
        listener.set_nonblocking(true)?;
        let accept_thread = std::thread::Builder::new()
            .name(format!("rpc-accept-{}", addr.port))
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _peer)) => {
                            let h = handler.clone();
                            let cstop = stop2.clone();
                            let _ = stream.set_nodelay(true);
                            let _ = std::thread::Builder::new()
                                .name("rpc-conn".into())
                                .spawn(move || {
                                    let _ = Self::conn_loop(&mut stream, h, cstop);
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            crate::util::clock::real_sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn rpc accept thread");
        Ok(RpcServer { addr, stop, accept_thread: Some(accept_thread) })
    }

    fn conn_loop(
        stream: &mut TcpStream,
        handler: Arc<dyn RpcHandler>,
        stop: Arc<AtomicBool>,
    ) -> Result<(), RpcError> {
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        let mut scratch = Vec::new();
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let frame = match read_exact_frame(stream) {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()),
                Err(RpcError::Io(e))
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue
                }
                Err(e) => return Err(e),
            };
            if frame.len() < 10 {
                return Err(RpcError::Wire(WireError("short request frame".into())));
            }
            let call_id = u64::from_le_bytes(frame[0..8].try_into().unwrap());
            let method = u16::from_le_bytes(frame[8..10].try_into().unwrap());
            let result = handler.handle(method, &frame[10..]);
            let mut head = Vec::with_capacity(9);
            head.extend_from_slice(&call_id.to_le_bytes());
            match result {
                Ok(payload) => {
                    head.push(0);
                    write_frame_buf(stream, &mut scratch, &head, &payload)?;
                }
                Err(msg) => {
                    head.push(1);
                    write_frame_buf(stream, &mut scratch, &head, msg.as_bytes())?;
                }
            }
        }
    }

    pub fn addr(&self) -> HostPort {
        self.addr.clone()
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Blocking RPC client over a single connection; `call` is `&self` and
/// serialized by an internal lock so it can be shared across threads.
pub struct RpcClient {
    stream: std::sync::Mutex<(TcpStream, Vec<u8>)>,
    next_id: AtomicU64,
    pub peer: HostPort,
}

impl RpcClient {
    pub fn connect(addr: &HostPort) -> Result<RpcClient, RpcError> {
        let stream = TcpStream::connect((addr.host.as_str(), addr.port))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream: std::sync::Mutex::new((stream, Vec::new())),
            next_id: AtomicU64::new(1),
            peer: addr.clone(),
        })
    }

    pub fn connect_timeout(addr: &HostPort, timeout: Duration) -> Result<RpcClient, RpcError> {
        let sockaddr: std::net::SocketAddr = format!("{addr}")
            .parse()
            .map_err(|e| RpcError::Io(std::io::Error::other(format!("bad addr: {e}"))))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream: std::sync::Mutex::new((stream, Vec::new())),
            next_id: AtomicU64::new(1),
            peer: addr.clone(),
        })
    }

    /// Issue one request and block for its response.
    pub fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, RpcError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.stream.lock().expect("rpc client poisoned");
        let (ref mut stream, ref mut scratch) = *guard;
        let mut head = [0u8; 10];
        head[..8].copy_from_slice(&id.to_le_bytes());
        head[8..].copy_from_slice(&method.to_le_bytes());
        // lint:allow(blocking-under-lock, reason = "one in-flight call per connection by design; the stream lock IS the request pipeline")
        write_frame_buf(stream, scratch, &head, payload)?;
        loop {
            // lint:allow(blocking-under-lock, reason = "response read is the second half of the same pipelined call")
            let frame = read_exact_frame(stream)?.ok_or(RpcError::Closed)?;
            if frame.len() < 9 {
                return Err(RpcError::Wire(WireError("short response frame".into())));
            }
            let rid = u64::from_le_bytes(frame[0..8].try_into().unwrap());
            if rid != id {
                // Single in-flight call per connection (we hold the lock),
                // so a mismatch means protocol corruption.
                return Err(RpcError::Wire(WireError(format!(
                    "response id mismatch: {rid} != {id}"
                ))));
            }
            return match frame[8] {
                0 => Ok(frame[9..].to_vec()),
                1 => Err(RpcError::Remote(
                    String::from_utf8_lossy(&frame[9..]).into_owned(),
                )),
                s => Err(RpcError::Wire(WireError(format!("bad status {s}")))),
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> RpcServer {
        RpcServer::serve(Arc::new(|method: u16, payload: &[u8]| {
            if method == 99 {
                Err("boom".to_string())
            } else {
                let mut out = payload.to_vec();
                out.extend_from_slice(&method.to_le_bytes());
                Ok(out)
            }
        }))
        .unwrap()
    }

    #[test]
    fn echo_round_trip() {
        let srv = echo_server();
        let cli = RpcClient::connect(&srv.addr()).unwrap();
        let resp = cli.call(7, b"hello").unwrap();
        assert_eq!(&resp[..5], b"hello");
        assert_eq!(u16::from_le_bytes(resp[5..7].try_into().unwrap()), 7);
    }

    #[test]
    fn remote_error_propagates() {
        let srv = echo_server();
        let cli = RpcClient::connect(&srv.addr()).unwrap();
        match cli.call(99, b"") {
            Err(RpcError::Remote(m)) => assert_eq!(m, "boom"),
            other => panic!("expected remote error, got {other:?}"),
        }
    }

    #[test]
    fn concurrent_clients() {
        let srv = echo_server();
        let addr = srv.addr();
        let mut handles = Vec::new();
        for t in 0..8 {
            let addr = addr.clone();
            handles.push(std::thread::spawn(move || {
                let cli = RpcClient::connect(&addr).unwrap();
                for i in 0..50u32 {
                    let msg = format!("t{t}-{i}");
                    let resp = cli.call(1, msg.as_bytes()).unwrap();
                    assert_eq!(&resp[..msg.len()], msg.as_bytes());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shared_client_across_threads() {
        let srv = echo_server();
        let cli = Arc::new(RpcClient::connect(&srv.addr()).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let cli = cli.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25u32 {
                    let msg = format!("x{t}-{i}");
                    let resp = cli.call(2, msg.as_bytes()).unwrap();
                    assert_eq!(&resp[..msg.len()], msg.as_bytes());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn large_payload() {
        let srv = echo_server();
        let cli = RpcClient::connect(&srv.addr()).unwrap();
        let big = vec![0xABu8; 4 << 20];
        let resp = cli.call(3, &big).unwrap();
        assert_eq!(resp.len(), big.len() + 2);
    }

    #[test]
    fn server_shutdown_rejects_new_connections() {
        let srv = echo_server();
        let addr = srv.addr();
        srv.shutdown();
        drop(srv);
        crate::util::clock::real_sleep(Duration::from_millis(50));
        // Either connect fails or the first call fails — both acceptable.
        match RpcClient::connect(&addr) {
            Err(_) => {}
            Ok(cli) => {
                assert!(cli.call(1, b"x").is_err());
            }
        }
    }
}
