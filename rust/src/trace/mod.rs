//! Causal job-lifecycle tracing: spans across gateway → RM → AM → executor.
//!
//! The metrics plane (PR 3) answers *what is the value now*; the scheduler
//! states (PR 5) answer *where does the job stand*.  This module answers
//! *why*: which stage — queue wait, gang placement, container launch,
//! executor registration, spec distribution, running — consumed a job's
//! time, and which scheduler decision blocked it.
//!
//! Design mirrors the metrics plane deliberately:
//!
//! * One bounded [`SpanStore`] per job (ring-buffer discipline from
//!   `metrics::Series`: at capacity the oldest span is evicted).
//! * The off switch leaves the hot path lock-free: every public method
//!   checks a plain `enabled` bool *before* touching the store's mutex,
//!   exactly like `Registry::observe_task`'s `interval_ms == 0` early
//!   return.
//! * Keys: `tony.trace.enable`, `tony.trace.max-spans-per-job`,
//!   `tony.trace.export` (see `docs/TRACING.md` / `docs/CONFIGURATION.md`).
//!
//! On top of the raw spans sits the **critical-path analyzer**
//! ([`SpanStore::trace_json`]): it folds the span tree into a per-stage
//! latency breakdown, names the dominant stage, and surfaces the scheduler
//! decision that blocked the job the longest (e.g. "gang 7 waited 12.4 s
//! for queue 'prod' headroom; 2 preemption rounds").

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::json::Json;
use crate::util::clock::{Clock, SystemClock};
use crate::xmlconf::Configuration;

/// The `tony.trace.*` configuration surface.
#[derive(Debug, Clone)]
pub struct TraceConf {
    /// `tony.trace.enable` — master switch (default true).  When false the
    /// job gets a disabled store: every span call is a branch on a plain
    /// bool, no lock is ever taken.
    pub enable: bool,
    /// `tony.trace.max-spans-per-job` — ring capacity (default 256).  At
    /// capacity the oldest span is evicted, `metrics::Series` style.
    pub max_spans_per_job: usize,
    /// `tony.trace.export` — when false the trace is collected (CLI and
    /// API can read it) but not persisted into the job's history record.
    pub export: bool,
}

impl Default for TraceConf {
    fn default() -> TraceConf {
        TraceConf { enable: true, max_spans_per_job: 256, export: true }
    }
}

impl TraceConf {
    pub fn from_conf(conf: &Configuration) -> TraceConf {
        let d = TraceConf::default();
        TraceConf {
            enable: conf.get_bool("tony.trace.enable", d.enable),
            max_spans_per_job: conf
                .get_u64("tony.trace.max-spans-per-job", d.max_spans_per_job as u64)
                .max(8) as usize,
            export: conf.get_bool("tony.trace.export", d.export),
        }
    }
}

/// The six lifecycle stages the critical-path analyzer attributes time to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Gateway accept → a submit worker picks the job up.
    Queued,
    /// Asks submitted → every task container granted (gang placement,
    /// reservations, and preemption rounds all land here).
    Scheduling,
    /// First grant → every executor launched in its container.
    Launching,
    /// Executors launched → every task registered back with the AM.
    Registering,
    /// Cluster spec built → every task fetched it (TF_CONFIG distribution).
    SpecSync,
    /// Spec distributed → the attempt ends.
    Running,
}

impl Stage {
    pub const ALL: [Stage; 6] = [
        Stage::Queued,
        Stage::Scheduling,
        Stage::Launching,
        Stage::Registering,
        Stage::SpecSync,
        Stage::Running,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Queued => "queued",
            Stage::Scheduling => "scheduling",
            Stage::Launching => "launching",
            Stage::Registering => "registering",
            Stage::SpecSync => "spec-sync",
            Stage::Running => "running",
        }
    }
}

/// A lightweight causal reference: trace id (job + attempt) plus the span
/// it points at.  Minted by [`SpanStore::context`]; carried in log lines so
/// `grep <job-id>` correlates logs with the span tree.
#[derive(Debug, Clone)]
pub struct TraceContext {
    pub trace_id: String,
    pub span: u64,
    pub parent: Option<u64>,
}

/// One recorded interval (or instantaneous event when `end_ms == start_ms`
/// at creation).  `end_ms == None` means the span is still open.
#[derive(Debug, Clone)]
pub struct Span {
    pub id: u64,
    pub parent: Option<u64>,
    pub name: String,
    pub stage: Stage,
    pub start_ms: u64,
    pub end_ms: Option<u64>,
    pub attrs: Vec<(String, String)>,
}

impl Span {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id);
        match self.parent {
            Some(p) => j.set("parent", p),
            None => j.set("parent", Json::Null),
        };
        j.set("name", self.name.as_str());
        j.set("stage", self.stage.as_str());
        j.set("start_ms", self.start_ms);
        match self.end_ms {
            Some(e) => j.set("end_ms", e),
            None => j.set("end_ms", Json::Null),
        };
        let mut attrs = Json::obj();
        for (k, v) in &self.attrs {
            attrs.set(k.as_str(), v.as_str());
        }
        j.set("attrs", attrs);
        j
    }
}

struct StoreInner {
    spans: VecDeque<Span>,
    next_span: u64,
    attempt: u32,
    /// One stage span may be open per stage at a time (re-opening after a
    /// close starts a fresh span; the analyzer sums all of them).
    open_stages: BTreeMap<Stage, u64>,
    /// The currently open scheduler-decision span, with the (reason,
    /// detail) it was opened for — repeats of the same verdict accrue
    /// duration on it instead of spamming new spans.
    open_decision: Option<(u64, String, String)>,
}

impl StoreInner {
    fn push(&mut self, cap: usize, span: Span) {
        if self.spans.len() == cap {
            if let Some(old) = self.spans.pop_front() {
                // An evicted span must not leave dangling open-state.
                self.open_stages.retain(|_, id| *id != old.id);
                if matches!(&self.open_decision, Some((id, _, _)) if *id == old.id) {
                    self.open_decision = None;
                }
            }
        }
        self.spans.push_back(span);
    }

    fn close(&mut self, id: u64, now: u64) {
        if let Some(s) = self.spans.iter_mut().find(|s| s.id == id) {
            if s.end_ms.is_none() {
                s.end_ms = Some(now.max(s.start_ms));
            }
        }
    }

    fn annotate(&mut self, id: u64, key: &str, value: String) {
        if let Some(s) = self.spans.iter_mut().find(|s| s.id == id) {
            if let Some(a) = s.attrs.iter_mut().find(|(k, _)| k == key) {
                a.1 = value;
            } else {
                s.attrs.push((key.to_string(), value));
            }
        }
    }
}

/// The per-job span ring.  Cheap to share (`Arc`), safe to hammer from the
/// gateway, RM, AM, and executor threads; a disabled store never locks.
pub struct SpanStore {
    enabled: bool,
    export: bool,
    job_id: u64,
    cap: usize,
    clock: Arc<dyn Clock>,
    inner: Mutex<StoreInner>,
}

impl SpanStore {
    pub fn new(conf: &TraceConf, clock: Arc<dyn Clock>, job_id: u64) -> Arc<SpanStore> {
        Arc::new(SpanStore {
            enabled: conf.enable,
            export: conf.export,
            job_id,
            cap: conf.max_spans_per_job,
            clock,
            inner: Mutex::new(StoreInner {
                spans: VecDeque::new(),
                next_span: 1,
                attempt: 0,
                open_stages: BTreeMap::new(),
                open_decision: None,
            }),
        })
    }

    /// A store that records nothing and never takes its lock.
    pub fn disabled() -> Arc<SpanStore> {
        SpanStore::new(
            &TraceConf { enable: false, ..TraceConf::default() },
            SystemClock::shared(),
            0,
        )
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Whether this trace should be persisted into the job's history
    /// record (`tony.trace.export`).
    pub fn export(&self) -> bool {
        self.enabled && self.export
    }

    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    pub fn set_attempt(&self, attempt: u32) {
        if !self.enabled {
            return;
        }
        self.inner.lock().unwrap().attempt = attempt;
    }

    /// Mint a causal reference for log correlation.
    pub fn context(&self, span: u64, parent: Option<u64>) -> TraceContext {
        let attempt = if self.enabled { self.inner.lock().unwrap().attempt } else { 0 };
        TraceContext { trace_id: format!("job-{}.{attempt}", self.job_id), span, parent }
    }

    /// Open a span.  Returns its id, or 0 when tracing is disabled.
    pub fn start(&self, stage: Stage, name: &str, parent: Option<u64>) -> u64 {
        if !self.enabled {
            return 0;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_span;
        inner.next_span += 1;
        let span = Span {
            id,
            parent,
            name: name.to_string(),
            stage,
            start_ms: now,
            end_ms: None,
            attrs: Vec::new(),
        };
        inner.push(self.cap, span);
        id
    }

    /// Close a span (no-op for unknown / already-closed / evicted ids).
    pub fn end(&self, id: u64) {
        if !self.enabled || id == 0 {
            return;
        }
        let now = self.clock.now_ms();
        self.inner.lock().unwrap().close(id, now);
    }

    /// Record an instantaneous event span.
    pub fn event(&self, stage: Stage, name: &str, parent: Option<u64>, attrs: &[(&str, String)]) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_span;
        inner.next_span += 1;
        let span = Span {
            id,
            parent,
            name: name.to_string(),
            stage,
            start_ms: now,
            end_ms: Some(now),
            attrs: attrs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        };
        inner.push(self.cap, span);
    }

    /// Attach (or overwrite) an attribute on an existing span.
    pub fn annotate(&self, id: u64, key: &str, value: String) {
        if !self.enabled || id == 0 {
            return;
        }
        self.inner.lock().unwrap().annotate(id, key, value);
    }

    /// Open the canonical span for `stage` (the one the critical-path
    /// analyzer attributes stage time to).  No-op if one is already open —
    /// callers on racy paths (AM loop vs RPC handlers) can all call this.
    pub fn start_stage(&self, stage: Stage) -> u64 {
        if !self.enabled {
            return 0;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        if let Some(id) = inner.open_stages.get(&stage) {
            return *id;
        }
        let id = inner.next_span;
        inner.next_span += 1;
        let span = Span {
            id,
            parent: None,
            name: stage.as_str().to_string(),
            stage,
            start_ms: now,
            end_ms: None,
            attrs: Vec::new(),
        };
        inner.push(self.cap, span);
        inner.open_stages.insert(stage, id);
        id
    }

    /// Close the open canonical span for `stage`, if any.
    pub fn end_stage(&self, stage: Stage) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        if let Some(id) = inner.open_stages.remove(&stage) {
            inner.close(id, now);
        }
    }

    /// The open canonical span id for `stage` (parent for sub-spans).
    pub fn stage_span(&self, stage: Stage) -> Option<u64> {
        if !self.enabled {
            return None;
        }
        self.inner.lock().unwrap().open_stages.get(&stage).copied()
    }

    /// Close every open span — the job terminalized; nothing may stay
    /// open in the exported shape.
    pub fn end_all(&self) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        inner.open_stages.clear();
        inner.open_decision = None;
        for s in inner.spans.iter_mut() {
            if s.end_ms.is_none() {
                s.end_ms = Some(now.max(s.start_ms));
            }
        }
    }

    /// Record a scheduler verdict for this app.  Repeats of the *same*
    /// blocking verdict accrue duration on one open span (that is what
    /// turns "the scheduler said WAITING_HEADROOM 400 times" into "gang 7
    /// waited 12.4 s for queue 'prod' headroom"); a different verdict
    /// closes the old span and opens a new one.  `PLACED_ALL` closes the
    /// open decision; `PREEMPTION_PLANNED` counts a round on it.
    pub fn scheduler_decision(&self, gang: Option<u64>, reason: &str, detail: &str) {
        if !self.enabled {
            return;
        }
        let now = self.clock.now_ms();
        let mut inner = self.inner.lock().unwrap();
        let parent = inner.open_stages.get(&Stage::Scheduling).copied();
        match reason {
            "PLACED_ALL" => {
                if let Some((id, _, _)) = inner.open_decision.take() {
                    inner.annotate(id, "resolution", "placed".to_string());
                    inner.close(id, now);
                }
                let id = inner.next_span;
                inner.next_span += 1;
                let mut attrs = vec![("reason".to_string(), reason.to_string())];
                if let Some(g) = gang {
                    attrs.push(("gang".to_string(), g.to_string()));
                }
                if !detail.is_empty() {
                    attrs.push(("detail".to_string(), detail.to_string()));
                }
                let span = Span {
                    id,
                    parent,
                    name: "sched.placed".to_string(),
                    stage: Stage::Scheduling,
                    start_ms: now,
                    end_ms: Some(now),
                    attrs,
                };
                inner.push(self.cap, span);
            }
            "PREEMPTION_PLANNED" => {
                if let Some((id, _, _)) = inner.open_decision.clone() {
                    let rounds = inner
                        .spans
                        .iter()
                        .find(|s| s.id == id)
                        .and_then(|s| s.attrs.iter().find(|(k, _)| k == "preempt_rounds"))
                        .and_then(|(_, v)| v.parse::<u64>().ok())
                        .unwrap_or(0);
                    inner.annotate(id, "preempt_rounds", (rounds + 1).to_string());
                    inner.annotate(id, "preempt_detail", detail.to_string());
                } else {
                    let id = inner.next_span;
                    inner.next_span += 1;
                    let mut attrs = vec![
                        ("reason".to_string(), reason.to_string()),
                        ("detail".to_string(), detail.to_string()),
                    ];
                    if let Some(g) = gang {
                        attrs.push(("gang".to_string(), g.to_string()));
                    }
                    let span = Span {
                        id,
                        parent,
                        name: "sched.preemption".to_string(),
                        stage: Stage::Scheduling,
                        start_ms: now,
                        end_ms: Some(now),
                        attrs,
                    };
                    inner.push(self.cap, span);
                }
            }
            "RESERVED" => {
                // A reservation refines the open WAITING_FREE verdict rather
                // than replacing it — annotating keeps one span accruing the
                // whole wait instead of churning WAITING_FREE / RESERVED pairs.
                if let Some((id, _, _)) = inner.open_decision.clone() {
                    inner.annotate(id, "reserved", detail.to_string());
                } else {
                    let id = inner.next_span;
                    inner.next_span += 1;
                    let mut attrs = vec![
                        ("reason".to_string(), reason.to_string()),
                        ("detail".to_string(), detail.to_string()),
                    ];
                    if let Some(g) = gang {
                        attrs.push(("gang".to_string(), g.to_string()));
                    }
                    let span = Span {
                        id,
                        parent,
                        name: "sched.reserved".to_string(),
                        stage: Stage::Scheduling,
                        start_ms: now,
                        end_ms: Some(now),
                        attrs,
                    };
                    inner.push(self.cap, span);
                }
            }
            _ => {
                if matches!(&inner.open_decision, Some((_, r, d)) if r == reason && d == detail) {
                    return; // same verdict: the open span keeps accruing
                }
                if let Some((id, _, _)) = inner.open_decision.take() {
                    inner.close(id, now);
                }
                let id = inner.next_span;
                inner.next_span += 1;
                let mut attrs = vec![
                    ("reason".to_string(), reason.to_string()),
                    ("detail".to_string(), detail.to_string()),
                ];
                if let Some(g) = gang {
                    attrs.push(("gang".to_string(), g.to_string()));
                }
                let span = Span {
                    id,
                    parent,
                    name: "sched.decision".to_string(),
                    stage: Stage::Scheduling,
                    start_ms: now,
                    end_ms: None,
                    attrs,
                };
                inner.push(self.cap, span);
                inner.open_decision = Some((id, reason.to_string(), detail.to_string()));
            }
        }
    }

    /// Per-stage milliseconds as of now (open stage spans count up to the
    /// current clock).  Programmatic form of the critical-path breakdown —
    /// the benches build their attribution tables from this.
    pub fn stage_millis(&self) -> Vec<(Stage, u64)> {
        if !self.enabled {
            return Vec::new();
        }
        let now = self.clock.now_ms();
        let inner = self.inner.lock().unwrap();
        let mut totals: BTreeMap<Stage, u64> = BTreeMap::new();
        for s in &inner.spans {
            if s.name != s.stage.as_str() {
                continue; // only canonical stage spans carry stage time
            }
            let end = s.end_ms.unwrap_or(now).max(s.start_ms);
            *totals.entry(s.stage).or_insert(0) += end - s.start_ms;
        }
        Stage::ALL
            .iter()
            .filter_map(|st| totals.get(st).map(|ms| (*st, *ms)))
            .collect()
    }

    /// The full exported shape: trace header, span list, critical path.
    /// This is what `GET /api/v1/jobs/{id}/trace` serves live and what
    /// `JobRecord.trace` persists at completion.
    pub fn trace_json(&self) -> Json {
        let mut j = Json::obj();
        if !self.enabled {
            j.set("enabled", false);
            j.set("spans", Json::Arr(Vec::new()));
            return j;
        }
        let now = self.clock.now_ms();
        let inner = self.inner.lock().unwrap();
        let mut header = Json::obj();
        header.set("job", self.job_id);
        header.set("attempt", inner.attempt as u64);
        header.set("trace_id", format!("job-{}.{}", self.job_id, inner.attempt));
        j.set("enabled", true);
        j.set("trace", header);
        j.set(
            "spans",
            Json::Arr(inner.spans.iter().map(|s| s.to_json()).collect()),
        );
        j.set("critical_path", critical_path(inner.spans.iter(), now));
        j
    }
}

/// Fold spans into the critical-path JSON: per-stage millis, the dominant
/// stage, and the longest-lived blocking scheduler decision rendered as a
/// sentence.
fn critical_path<'a>(spans: impl Iterator<Item = &'a Span>, now: u64) -> Json {
    let mut totals: BTreeMap<Stage, u64> = BTreeMap::new();
    let mut blocking: Option<(u64, String)> = None; // (duration, text)
    let mut preempt_note = String::new();
    for s in spans {
        let end = s.end_ms.unwrap_or(now).max(s.start_ms);
        let dur = end - s.start_ms;
        if s.name == s.stage.as_str() {
            *totals.entry(s.stage).or_insert(0) += dur;
        }
        if s.name == "sched.decision" {
            let attr = |k: &str| {
                s.attrs
                    .iter()
                    .find(|(key, _)| key == k)
                    .map(|(_, v)| v.as_str())
                    .unwrap_or("")
            };
            let gang = attr("gang");
            let gang_txt =
                if gang.is_empty() { "the job".to_string() } else { format!("gang {gang}") };
            let reason = attr("reason");
            let detail = attr("detail");
            let secs = dur as f64 / 1000.0;
            let mut text = if reason.starts_with("WAITING") {
                format!("{gang_txt} waited {secs:.1} s {detail}")
            } else {
                format!("{gang_txt} {detail}")
            };
            let rounds = attr("preempt_rounds");
            if !rounds.is_empty() {
                let plural = if rounds == "1" { "round" } else { "rounds" };
                text.push_str(&format!("; {rounds} preemption {plural}"));
            }
            if blocking.as_ref().map(|(d, _)| dur >= *d).unwrap_or(true) {
                blocking = Some((dur, text));
            }
        }
        if s.name == "sched.preemption" {
            let detail = s
                .attrs
                .iter()
                .find(|(k, _)| k == "detail")
                .map(|(_, v)| v.clone())
                .unwrap_or_default();
            preempt_note = detail;
        }
    }
    let mut stages = Json::obj();
    for (st, ms) in &totals {
        stages.set(st.as_str(), *ms);
    }
    let dominant = totals
        .iter()
        .max_by_key(|(_, ms)| **ms)
        .map(|(st, _)| st.as_str().to_string());
    let mut j = Json::obj();
    j.set("stages", stages);
    match dominant {
        Some(d) => j.set("dominant_stage", d),
        None => j.set("dominant_stage", Json::Null),
    };
    match blocking {
        Some((_, text)) => j.set("blocking_decision", text),
        None => {
            if preempt_note.is_empty() {
                j.set("blocking_decision", Json::Null)
            } else {
                j.set("blocking_decision", preempt_note)
            }
        }
    };
    j
}

/// Render a trace JSON (the `/trace` endpoint shape) as an ASCII timeline
/// for `tony trace <job-id>`.
pub fn render_ascii(trace: &Json) -> String {
    let mut out = String::new();
    if trace.at(&["enabled"]).and_then(|j| j.as_bool()) == Some(false) {
        out.push_str("tracing is disabled for this job (tony.trace.enable=false)\n");
        return out;
    }
    let job = trace.at(&["trace", "job"]).and_then(|j| j.as_u64()).unwrap_or(0);
    let attempt = trace.at(&["trace", "attempt"]).and_then(|j| j.as_u64()).unwrap_or(0);
    out.push_str(&format!("trace job-{job}.{attempt}\n"));
    let empty: Vec<Json> = Vec::new();
    let spans = trace
        .at(&["spans"])
        .and_then(|j| j.as_arr().cloned())
        .unwrap_or(empty);
    // Time origin and scale across all spans.
    let mut t0 = u64::MAX;
    let mut t1 = 0u64;
    for s in &spans {
        let start = s.at(&["start_ms"]).and_then(|j| j.as_u64()).unwrap_or(0);
        let end = s.at(&["end_ms"]).and_then(|j| j.as_u64()).unwrap_or(start);
        t0 = t0.min(start);
        t1 = t1.max(end.max(start));
    }
    if t0 == u64::MAX {
        out.push_str("  (no spans recorded)\n");
        return out;
    }
    let total = (t1 - t0).max(1);
    const WIDTH: usize = 40;
    for s in &spans {
        let name = s.at(&["name"]).and_then(|j| j.as_str()).unwrap_or("?");
        let stage = s.at(&["stage"]).and_then(|j| j.as_str()).unwrap_or("?");
        let start = s.at(&["start_ms"]).and_then(|j| j.as_u64()).unwrap_or(0);
        let end = s.at(&["end_ms"]).and_then(|j| j.as_u64()).unwrap_or(start).max(start);
        let off = ((start - t0) as usize * WIDTH) / total as usize;
        let mut len = ((end - start) as usize * WIDTH) / total as usize;
        if len == 0 {
            len = 1;
        }
        let off = off.min(WIDTH - 1);
        let len = len.min(WIDTH - off);
        let bar: String = " ".repeat(off) + &"#".repeat(len) + &" ".repeat(WIDTH - off - len);
        let is_stage = name == stage;
        let label = if is_stage { name.to_string() } else { format!("  {name}") };
        let reason = s
            .at(&["attrs", "reason"])
            .and_then(|j| j.as_str())
            .map(|r| format!("  [{r}]"))
            .unwrap_or_default();
        out.push_str(&format!(
            "  {label:<22} |{bar}| {:>8} ms{reason}\n",
            end - start
        ));
    }
    let cp = trace.at(&["critical_path"]);
    if let Some(cp) = cp {
        if let Some(dom) = cp.at(&["dominant_stage"]).and_then(|j| j.as_str()) {
            let ms = cp.at(&["stages", dom]).and_then(|j| j.as_u64()).unwrap_or(0);
            out.push_str(&format!("critical path: {dom} ({ms} ms)"));
            if let Some(b) = cp.at(&["blocking_decision"]).and_then(|j| j.as_str()) {
                out.push_str(&format!(" — {b}"));
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ManualClock;

    fn manual_store(cap: usize) -> (Arc<SpanStore>, Arc<ManualClock>) {
        let clock = ManualClock::shared();
        let conf = TraceConf { enable: true, max_spans_per_job: cap, export: true };
        let generic: Arc<dyn Clock> = clock.clone();
        (SpanStore::new(&conf, generic, 7), clock)
    }

    #[test]
    fn disabled_store_records_nothing_and_returns_zero_ids() {
        let store = SpanStore::disabled();
        assert!(!store.enabled());
        assert_eq!(store.start(Stage::Queued, "queued", None), 0);
        assert_eq!(store.start_stage(Stage::Scheduling), 0);
        store.end(0);
        store.end_stage(Stage::Scheduling);
        store.scheduler_decision(Some(1), "WAITING_HEADROOM", "for queue 'x' headroom");
        let j = store.trace_json();
        assert_eq!(j.at(&["enabled"]).and_then(|v| v.as_bool()), Some(false));
        assert!(j.at(&["spans"]).and_then(|v| v.as_arr()).unwrap().is_empty());
    }

    #[test]
    fn ring_evicts_oldest_span_at_capacity() {
        let (store, clock) = manual_store(8);
        for i in 0..12 {
            clock.advance_ms(1);
            store.event(Stage::Running, &format!("ev{i}"), None, &[]);
        }
        let j = store.trace_json();
        let spans = j.at(&["spans"]).and_then(|v| v.as_arr()).unwrap().clone();
        assert_eq!(spans.len(), 8, "capacity bound holds");
        let first = spans[0].at(&["name"]).and_then(|v| v.as_str()).unwrap().to_string();
        assert_eq!(first, "ev4", "oldest evicted first");
    }

    #[test]
    fn eviction_clears_dangling_open_state() {
        let (store, clock) = manual_store(8);
        let qid = store.start_stage(Stage::Queued);
        assert_eq!(store.stage_span(Stage::Queued), Some(qid));
        for i in 0..8 {
            clock.advance_ms(1);
            store.event(Stage::Running, &format!("ev{i}"), None, &[]);
        }
        // The queued stage span was evicted; its open handle must be gone.
        assert_eq!(store.stage_span(Stage::Queued), None);
        store.end_stage(Stage::Queued); // must not panic or corrupt
    }

    #[test]
    fn stage_spans_accrue_time_and_close() {
        let (store, clock) = manual_store(64);
        store.start_stage(Stage::Queued);
        clock.advance_ms(120);
        store.end_stage(Stage::Queued);
        store.start_stage(Stage::Scheduling);
        clock.advance_ms(400);
        // Open span counts up to "now".
        let ms: BTreeMap<Stage, u64> = store.stage_millis().into_iter().collect();
        assert_eq!(ms.get(&Stage::Queued), Some(&120));
        assert_eq!(ms.get(&Stage::Scheduling), Some(&400));
        // start_stage is idempotent while open.
        let a = store.start_stage(Stage::Scheduling);
        let b = store.start_stage(Stage::Scheduling);
        assert_eq!(a, b);
    }

    #[test]
    fn same_decision_accrues_different_decision_rotates() {
        let (store, clock) = manual_store(64);
        store.start_stage(Stage::Scheduling);
        store.scheduler_decision(Some(7), "WAITING_HEADROOM", "for queue 'prod' headroom");
        for _ in 0..50 {
            clock.advance_ms(100);
            store.scheduler_decision(Some(7), "WAITING_HEADROOM", "for queue 'prod' headroom");
        }
        clock.advance_ms(7_400);
        store.scheduler_decision(Some(7), "PREEMPTION_PLANNED", "2 victims");
        store.scheduler_decision(Some(7), "PREEMPTION_PLANNED", "1 victim");
        let j = store.trace_json();
        let spans = j.at(&["spans"]).and_then(|v| v.as_arr()).unwrap();
        let decisions: Vec<&Json> = spans
            .iter()
            .filter(|s| s.at(&["name"]).and_then(|v| v.as_str()) == Some("sched.decision"))
            .collect();
        assert_eq!(decisions.len(), 1, "repeat verdicts dedupe into one span");
        let blocking = j
            .at(&["critical_path", "blocking_decision"])
            .and_then(|v| v.as_str())
            .unwrap()
            .to_string();
        assert!(
            blocking.contains("gang 7 waited 12.4 s for queue 'prod' headroom"),
            "got: {blocking}"
        );
        assert!(blocking.contains("2 preemption rounds"), "got: {blocking}");
        // Placement closes the decision.
        store.scheduler_decision(Some(7), "PLACED_ALL", "");
        let j = store.trace_json();
        let spans = j.at(&["spans"]).and_then(|v| v.as_arr()).unwrap();
        assert!(spans
            .iter()
            .any(|s| s.at(&["name"]).and_then(|v| v.as_str()) == Some("sched.placed")));
        let open_decisions = spans.iter().any(|s| {
            s.at(&["name"]).and_then(|v| v.as_str()) == Some("sched.decision")
                && s.at(&["end_ms"]).map(|v| matches!(v, Json::Null)).unwrap_or(false)
        });
        assert!(!open_decisions, "PLACED_ALL closes the open decision span");
    }

    #[test]
    fn critical_path_names_dominant_stage() {
        let (store, clock) = manual_store(64);
        store.start_stage(Stage::Queued);
        clock.advance_ms(10);
        store.end_stage(Stage::Queued);
        store.start_stage(Stage::Scheduling);
        clock.advance_ms(900);
        store.end_stage(Stage::Scheduling);
        store.start_stage(Stage::Running);
        clock.advance_ms(200);
        store.end_all();
        let j = store.trace_json();
        assert_eq!(
            j.at(&["critical_path", "dominant_stage"]).and_then(|v| v.as_str()),
            Some("scheduling")
        );
        assert_eq!(
            j.at(&["critical_path", "stages", "scheduling"]).and_then(|v| v.as_u64()),
            Some(900)
        );
    }

    #[test]
    fn end_all_closes_everything() {
        let (store, clock) = manual_store(64);
        store.start_stage(Stage::Queued);
        store.scheduler_decision(None, "WAITING_FREE", "for reserved nodes to drain");
        clock.advance_ms(50);
        store.end_all();
        let j = store.trace_json();
        for s in j.at(&["spans"]).and_then(|v| v.as_arr()).unwrap() {
            assert!(
                !matches!(s.at(&["end_ms"]), Some(Json::Null)),
                "open span survived end_all: {}",
                s.render()
            );
        }
    }

    #[test]
    fn ascii_render_mentions_stages_and_critical_path() {
        let (store, clock) = manual_store(64);
        store.start_stage(Stage::Queued);
        clock.advance_ms(100);
        store.end_stage(Stage::Queued);
        store.start_stage(Stage::Running);
        clock.advance_ms(300);
        store.end_all();
        let text = render_ascii(&store.trace_json());
        assert!(text.contains("queued"), "{text}");
        assert!(text.contains("critical path: running"), "{text}");
    }
}
