//! Minimal JSON codec (no serde in this offline build).
//!
//! Used for: the TF_CONFIG-style global cluster spec the AM broadcasts to
//! every TaskExecutor (paper §2.2), `artifacts/<preset>/meta.json` emitted
//! by the AOT pipeline, the portal's REST responses, and job-history
//! records.  Full RFC 8259 value model with escapes and \uXXXX (incl.
//! surrogate pairs); numbers are f64 like JavaScript.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap keeps key order deterministic -> byte-stable specs/goldens.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["cluster", "worker"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn render_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}

impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

impl From<Vec<String>> for Json {
    fn from(a: Vec<String>) -> Json {
        Json::Arr(a.into_iter().map(Json::Str).collect())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let v = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(v)
                                        .ok_or_else(|| self.err("bad surrogate pair"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(c);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{s}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair for U+1F600
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse(r#"{"a":1} x"#).is_err());
        assert!(Json::parse(r#""\ud800x""#).is_err());
    }

    #[test]
    fn render_round_trip() {
        let mut j = Json::obj();
        j.set("name", "tony").set("n", 42u64).set("ok", true);
        j.set("tags", Json::Arr(vec![Json::from("a"), Json::from("b\"q")]));
        let s = j.render();
        assert_eq!(Json::parse(&s).unwrap(), j);
        let p = j.render_pretty();
        assert_eq!(Json::parse(&p).unwrap(), j);
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::from(42u64).render(), "42");
        assert_eq!(Json::Num(1.5).render(), "1.5");
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 3, "neg": -2, "f": 1.25}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("neg").unwrap().as_u64(), None);
        assert_eq!(j.get("neg").unwrap().as_i64(), Some(-2));
        assert_eq!(j.get("f").unwrap().as_u64(), None);
        assert_eq!(j.get("f").unwrap().as_f64(), Some(1.25));
    }
}
