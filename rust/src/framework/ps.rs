//! Parameter-server task: owns a shard of the flat parameter vector and
//! applies the fused-Adam AOT kernel to it on every (aggregated) push.
//!
//! Chunk ownership: chunk `c` belongs to PS `c % n_ps`.  Sync mode
//! implements the barrier: a chunk at version `t` needs `n_workers`
//! gradient pushes tagged `t` before it advances to `t+1`; pulls for
//! `t+1` block on a condvar until then.  All heavy math (average + Adam)
//! runs through the PJRT engine — Python is nowhere near this path.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::net::rpc::{RpcHandler, RpcServer};
use crate::net::wire::Wire;
use crate::runtime::{EngineHandle, Tensor};
use crate::tdebug;

use super::protocol::*;

struct ChunkState {
    params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    version: u64,
    /// Sync-mode accumulator: (step, sum-of-grads, contributing workers).
    /// Tracking *which* workers contributed (not just a count) makes the
    /// barrier idempotent: a relaunched worker re-pushing the step its
    /// dead incarnation already delivered cannot double-count.
    pending: Option<(u64, Vec<f32>, BTreeSet<u32>)>,
}

struct Shard {
    /// chunk index -> state (only chunks this PS owns).
    chunks: Mutex<HashMap<u32, ChunkState>>,
    cond: Condvar,
    applied: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    kill: Arc<AtomicBool>,
}

/// A running PS shard (RPC server + engine).
pub struct PsServer {
    pub index: u32,
    pub n_ps: u32,
    server: RpcServer,
    shard: Arc<Shard>,
}

struct PsHandler {
    shard: Arc<Shard>,
    engine: EngineHandle,
    chunk_len: usize,
    index: u32,
    n_ps: u32,
}

impl PsHandler {
    fn owns(&self, chunk: u32) -> bool {
        chunk % self.n_ps == self.index
    }

    fn apply_update(
        &self,
        state: &mut ChunkState,
        grads: &[f32],
        scale: f32,
        lr: f32,
    ) -> Result<(), String> {
        // Average happens host-side (cheap, avoids another artifact);
        // Adam runs the AOT kernel.
        let avg: Vec<f32> = if scale == 1.0 {
            grads.to_vec()
        } else {
            grads.iter().map(|g| g * scale).collect()
        };
        let step_for_bias = (state.version + 1) as f32;
        // Move p/m/v into the engine call and put the results back —
        // zero full-chunk clones per update (§Perf L3 pass 3).  On error
        // the chunk is left empty and the task fails, which is exactly the
        // teardown path anyway.
        let out = self
            .engine
            .execute(
                "ps_adam",
                vec![
                    Tensor::f32(&[self.chunk_len], std::mem::take(&mut state.params)),
                    Tensor::f32(&[self.chunk_len], avg),
                    Tensor::f32(&[self.chunk_len], std::mem::take(&mut state.m)),
                    Tensor::f32(&[self.chunk_len], std::mem::take(&mut state.v)),
                    Tensor::scalar_f32(step_for_bias),
                    Tensor::scalar_f32(lr),
                ],
            )
            .map_err(|e| format!("ps_adam failed: {e}"))?;
        let mut it = out.into_iter();
        state.params = it.next().unwrap().into_f32().ok_or("bad p dtype")?;
        state.m = it.next().unwrap().into_f32().ok_or("bad m dtype")?;
        state.v = it.next().unwrap().into_f32().ok_or("bad v dtype")?;
        state.version += 1;
        self.shard.applied.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
}

impl RpcHandler for PsHandler {
    fn handle(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, String> {
        self.shard.bytes_in.fetch_add(payload.len() as u64, Ordering::Relaxed);
        let out = match method {
            PS_INIT => {
                let req = InitChunk::from_bytes(payload).map_err(|e| e.to_string())?;
                if !self.owns(req.chunk) {
                    return Err(format!("ps {} does not own chunk {}", self.index, req.chunk));
                }
                if req.params.len() != self.chunk_len {
                    return Err(format!(
                        "chunk {} length {} != chunk_len {}",
                        req.chunk,
                        req.params.len(),
                        self.chunk_len
                    ));
                }
                let mut chunks = self.shard.chunks.lock().unwrap();
                chunks.insert(
                    req.chunk,
                    ChunkState {
                        params: req.params,
                        m: req.m,
                        v: req.v,
                        version: req.version,
                        pending: None,
                    },
                );
                self.shard.cond.notify_all();
                Vec::new()
            }
            PS_PULL => {
                let req = PullRequest::from_bytes(payload).map_err(|e| e.to_string())?;
                if !self.owns(req.chunk) {
                    return Err(format!("ps {} does not own chunk {}", self.index, req.chunk));
                }
                let deadline = std::time::Instant::now()
                    + Duration::from_millis(req.timeout_ms.max(1));
                let mut chunks = self.shard.chunks.lock().unwrap();
                loop {
                    if let Some(state) = chunks.get(&req.chunk) {
                        if state.version >= req.min_version {
                            let resp = PullResponse {
                                version: state.version,
                                params: state.params.clone(),
                            };
                            break resp.to_bytes();
                        }
                    }
                    if self.shard.kill.load(Ordering::Relaxed) {
                        return Err("ps shutting down".to_string());
                    }
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Err(format!(
                            "pull timeout: chunk {} never reached version {}",
                            req.chunk, req.min_version
                        ));
                    }
                    let nap = (deadline - now).min(Duration::from_millis(100));
                    // lint:allow(blocking-under-lock, reason = "Condvar::wait_timeout atomically releases the chunk guard while parked")
                    let (guard, _) = self.shard.cond.wait_timeout(chunks, nap).unwrap();
                    chunks = guard;
                }
            }
            PS_PUSH => {
                let req = PushRequest::from_bytes(payload).map_err(|e| e.to_string())?;
                if !self.owns(req.chunk) {
                    return Err(format!("ps {} does not own chunk {}", self.index, req.chunk));
                }
                if req.grads.len() != self.chunk_len {
                    return Err("bad grad length".to_string());
                }
                let mut chunks = self.shard.chunks.lock().unwrap();
                let state = chunks
                    .get_mut(&req.chunk)
                    .ok_or_else(|| format!("chunk {} not initialized", req.chunk))?;
                if req.mode == MODE_ASYNC {
                    // lint:allow(blocking-under-lock, reason = "Adam kernel runs with the chunk's params moved out; readers must not observe the emptied chunk")
                    self.apply_update(state, &req.grads, 1.0, req.lr)?;
                    let version = state.version;
                    self.shard.cond.notify_all();
                    version.to_bytes()
                } else if req.step != state.version {
                    // Sync push tagged for a version this chunk is not at:
                    // either a straggler whose barrier already completed
                    // (step < version) or a worker ahead of a shard that a
                    // PS relaunch rolled back to an older checkpoint
                    // (step > version).  Drop the gradient and report the
                    // live version — the worker resyncs off the response
                    // instead of dying, which is what keeps survivors
                    // alive across surgical recoveries.
                    state.version.to_bytes()
                } else {
                    // Sync barrier path.
                    match &mut state.pending {
                        None => {
                            state.pending =
                                Some((req.step, req.grads.clone(), BTreeSet::from([req.worker])));
                        }
                        Some((step, acc, who)) => {
                            debug_assert_eq!(*step, req.step);
                            // Duplicate contributor (relaunched worker):
                            // the batch is deterministic per (worker,
                            // step), so the gradient is already in `acc`.
                            if who.insert(req.worker) {
                                for (a, g) in acc.iter_mut().zip(&req.grads) {
                                    *a += g;
                                }
                            }
                        }
                    }
                    let ready =
                        matches!(&state.pending, Some((_, _, who)) if who.len() >= req.n_workers as usize);
                    if ready {
                        let (_, acc, who) = state.pending.take().unwrap();
                        let scale = 1.0 / who.len() as f32;
                        // lint:allow(blocking-under-lock, reason = "Adam kernel runs with the chunk's params moved out; readers must not observe the emptied chunk")
                        self.apply_update(state, &acc, scale, req.lr)?;
                        self.shard.cond.notify_all();
                    }
                    let version = state.version;
                    version.to_bytes()
                }
            }
            PS_STATE => {
                let chunks = self.shard.chunks.lock().unwrap();
                let stats = PsStats {
                    owned_chunks: chunks.len() as u32,
                    min_version: chunks.values().map(|c| c.version).min().unwrap_or(0),
                    applied_updates: self.shard.applied.load(Ordering::Relaxed),
                    bytes_in: self.shard.bytes_in.load(Ordering::Relaxed),
                    bytes_out: self.shard.bytes_out.load(Ordering::Relaxed),
                };
                stats.to_bytes()
            }
            PS_MOMENTS => {
                let chunk = u32::from_bytes(payload).map_err(|e| e.to_string())?;
                let chunks = self.shard.chunks.lock().unwrap();
                let state = chunks
                    .get(&chunk)
                    .ok_or_else(|| format!("chunk {chunk} not initialized"))?;
                MomentsResponse {
                    version: state.version,
                    m: state.m.clone(),
                    v: state.v.clone(),
                }
                .to_bytes()
            }
            m => return Err(format!("unknown PS method {m}")),
        };
        self.shard.bytes_out.fetch_add(out.len() as u64, Ordering::Relaxed);
        Ok(out)
    }
}

impl PsServer {
    /// Start a PS shard's RPC server on an OS-assigned port.
    pub fn start(
        index: u32,
        n_ps: u32,
        engine: EngineHandle,
        kill: Arc<AtomicBool>,
    ) -> Result<PsServer> {
        let chunk_len = engine.meta().chunk_len;
        let shard = Arc::new(Shard {
            chunks: Mutex::new(HashMap::new()),
            cond: Condvar::new(),
            applied: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            kill,
        });
        let handler = PsHandler { shard: shard.clone(), engine, chunk_len, index, n_ps };
        let server = RpcServer::serve(Arc::new(handler))
            .map_err(|e| anyhow!("ps rpc server: {e}"))?;
        Ok(PsServer { index, n_ps, server, shard })
    }

    pub fn addr(&self) -> crate::util::HostPort {
        self.server.addr()
    }

    pub fn applied_updates(&self) -> u64 {
        self.shard.applied.load(Ordering::Relaxed)
    }

    pub fn shutdown(&self) {
        self.shard.kill.store(true, Ordering::Relaxed);
        // Wake any parked pulls so their connections can error out.
        let _g = self.shard.chunks.lock().unwrap();
        self.shard.cond.notify_all();
        drop(_g);
        self.server.shutdown();
    }
}

/// PS task main: start the shard server, report its port through
/// `on_port`, then serve until killed.  Returns the process exit code.
pub fn ps_main(
    index: u32,
    n_ps: u32,
    engine: EngineHandle,
    kill: Arc<AtomicBool>,
    metrics: MetricsCell,
    on_port: impl FnOnce(u16),
) -> i32 {
    let ps = match PsServer::start(index, n_ps, engine, kill.clone()) {
        Ok(ps) => ps,
        Err(e) => {
            crate::terror!("ps", "ps:{index} failed to start: {e}");
            return 1;
        }
    };
    tdebug!("ps", "ps:{index} serving on {}", ps.addr());
    on_port(ps.addr().port);
    while !kill.load(Ordering::Relaxed) {
        // Simulated child-process cadence (metrics refresh), real time.
        crate::util::clock::real_sleep(Duration::from_millis(20));
        let mut m = metrics.lock().unwrap();
        m.updates_applied = ps.applied_updates();
        m.mem_used_mb = {
            let chunks = ps.shard.chunks.lock().unwrap();
            // params + m + v, 4 bytes each.
            let bytes: usize = chunks.values().map(|c| c.params.len() * 4 * 3).sum();
            (bytes >> 20) as u64
        };
    }
    ps.shutdown();
    tdebug!("ps", "ps:{index} stopped cleanly");
    0
}
