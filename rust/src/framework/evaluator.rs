//! Evaluator task: an *untracked* service task (like TonY's evaluator /
//! TensorBoard job types) that periodically loads the chief's latest
//! checkpoint and scores it on held-out batches via the `eval_loss`
//! artifact.  It never gates job completion; the AM stops it once all
//! tracked tasks succeed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::checkpoint::CheckpointStore;
use crate::data::SyntheticCorpus;
use crate::runtime::{EngineHandle, Tensor};
use crate::tonyconf::TrainSpec;
use crate::{tdebug, tinfo};

use super::protocol::MetricsCell;

/// Evaluator main loop.  Returns the container exit code.
pub fn evaluator_main(
    index: u32,
    engine: EngineHandle,
    train: TrainSpec,
    kill: Arc<AtomicBool>,
    metrics: MetricsCell,
) -> i32 {
    let meta = engine.meta().clone();
    let store = CheckpointStore::new(&train.checkpoint_dir);
    // Held-out stream: worker indices never reach 20_000+.
    let corpus = SyntheticCorpus::new(meta.dims.vocab, train.seed);
    let mut last_step = u64::MAX;
    tdebug!("evaluator", "evaluator:{index} watching {}", train.checkpoint_dir);

    while !kill.load(Ordering::Relaxed) {
        match store.latest() {
            Ok(Some(ckpt)) if ckpt.step != last_step => {
                let tokens = corpus.batch(
                    20_000 + index,
                    ckpt.step,
                    meta.dims.batch,
                    meta.dims.seq_len,
                );
                let batch = Tensor::i32(&[meta.dims.batch, meta.dims.seq_len + 1], tokens);
                match engine.execute(
                    "eval_loss",
                    vec![Tensor::f32(&[meta.n_params], ckpt.params), batch],
                ) {
                    Ok(out) => {
                        let loss = out[0].scalar().unwrap_or(f32::NAN);
                        if !loss.is_finite() {
                            crate::terror!(
                                "evaluator",
                                "evaluator:{index} non-finite eval loss at step {}",
                                ckpt.step
                            );
                            return 1;
                        }
                        tinfo!(
                            "evaluator",
                            "evaluator:{index} step {}: held-out loss {loss:.4}",
                            ckpt.step
                        );
                        let mut m = metrics.lock().unwrap();
                        m.step = ckpt.step;
                        m.eval_loss = loss;
                        m.loss_history.push((ckpt.step, loss));
                        last_step = ckpt.step;
                    }
                    Err(e) => {
                        crate::terror!("evaluator", "evaluator:{index} eval failed: {e:#}");
                        return 1;
                    }
                }
            }
            _ => {}
        }
        // Checkpoint-watch cadence (simulated child process, real time).
        crate::util::clock::real_sleep(Duration::from_millis(50));
    }
    tdebug!("evaluator", "evaluator:{index} stopped cleanly");
    0
}
