//! Worker task: pull params → run the AOT `worker_step` (loss + grads) →
//! push gradient slices to the owning PS shards.  worker:0 is the chief:
//! it also initializes/restores parameters, checkpoints with exact Adam
//! moments, and runs periodic evals through the `eval_loss` artifact.
//!
//! Surgical recovery: when the AM relaunches a failed peer it hands the
//! survivors a patched cluster spec mid-run (through the executor's
//! heartbeat thread and the [`ReconfigCell`]).  A surviving worker
//! reconnects to the (possibly new) PS endpoints, resyncs its step off
//! the live parameter version, and keeps training — its container never
//! stops.  Barrier pulls are sliced so a pending reconfiguration (or a
//! kill) can interrupt them; transient PS outages are retried rather
//! than treated as fatal, because a replacement PS is usually seconds
//! away.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::{Checkpoint, CheckpointStore};
use crate::data::SyntheticCorpus;
use crate::net::rpc::RpcClient;
use crate::net::wire::Wire;
use crate::runtime::{EngineHandle, Tensor};
use crate::tonyconf::{TrainSpec, PS};
use crate::util::HostPort;
use crate::{tdebug, tinfo};

use super::protocol::*;

/// How long pulls wait for the barrier before declaring the job wedged.
const PULL_TIMEOUT_MS: u64 = 30_000;

/// Slice length for interruptible barrier pulls: a pending kill or
/// reconfiguration is noticed within this bound instead of after the
/// full pull timeout.
const PULL_SLICE_MS: u64 = 250;

/// A patched cluster spec delivered to a running task (surgical
/// recovery).  The executor's heartbeat thread fills it; the task drains
/// it at the top of its step loop.
pub type ReconfigCell = Arc<Mutex<Option<ClusterSpec>>>;

pub fn new_reconfig_cell() -> ReconfigCell {
    Arc::new(Mutex::new(None))
}

/// Everything a worker needs to run (assembled by the TaskExecutor from
/// the cluster spec + job conf).
pub struct WorkerContext {
    pub index: u32,
    pub n_workers: u32,
    pub ps_endpoints: Vec<HostPort>,
    pub engine: EngineHandle,
    pub train: TrainSpec,
    pub kill: Arc<AtomicBool>,
    pub metrics: MetricsCell,
    /// Cluster-spec version this worker launched at.
    pub spec_version: u64,
    /// Mid-run spec updates from the executor (None in direct harnesses).
    pub reconfig: Option<ReconfigCell>,
    /// Bound on the locally kept loss curve (from
    /// `MetricsSpec::loss_history_cap`); anything longer would be
    /// discarded at the AM, and an unbounded vector would make rollback
    /// truncation and heartbeat delta scans O(steps) under the shared
    /// metrics mutex.
    pub loss_history_cap: usize,
}

/// Client view of the sharded parameter store.
pub struct PsClient {
    clients: Vec<RpcClient>,
    n_params: usize,
    chunk_len: usize,
}

impl PsClient {
    pub fn connect(endpoints: &[HostPort], n_params: usize, chunk_len: usize) -> Result<PsClient> {
        let mut clients = Vec::with_capacity(endpoints.len());
        for ep in endpoints {
            clients.push(
                RpcClient::connect_timeout(ep, Duration::from_secs(5))
                    .map_err(|e| anyhow!("connecting to ps {ep}: {e}"))?,
            );
        }
        if clients.is_empty() {
            bail!("no parameter servers in cluster spec");
        }
        Ok(PsClient { clients, n_params, chunk_len })
    }

    pub fn n_chunks(&self) -> usize {
        self.n_params.div_ceil(self.chunk_len)
    }

    fn owner(&self, chunk: usize) -> &RpcClient {
        &self.clients[chunk % self.clients.len()]
    }

    /// Chunks shard `i` is expected to own once initialized.
    fn expected_owned(&self, i: usize) -> usize {
        let n_ps = self.clients.len();
        let n_chunks = self.n_chunks();
        if i >= n_chunks {
            0
        } else {
            (n_chunks - i).div_ceil(n_ps)
        }
    }

    /// True if any shard holds fewer chunks than it should — i.e. a PS
    /// was (re)started and its parameter state is gone.  The chief uses
    /// this to decide between joining warm shards as-is and re-seeding
    /// them from the last checkpoint.
    pub fn any_uninitialized(&self) -> Result<bool> {
        let stats = self.stats()?;
        Ok(stats
            .iter()
            .enumerate()
            .any(|(i, s)| (s.owned_chunks as usize) < self.expected_owned(i)))
    }

    /// Push initial chunk states (chief only).
    pub fn init(&self, params: &[f32], moments: Option<&(Vec<f32>, Vec<f32>)>, version: u64) -> Result<()> {
        for c in 0..self.n_chunks() {
            let lo = c * self.chunk_len;
            let hi = ((c + 1) * self.chunk_len).min(self.n_params);
            let mut chunk = vec![0f32; self.chunk_len];
            chunk[..hi - lo].copy_from_slice(&params[lo..hi]);
            let (mut m, mut v) = (vec![0f32; self.chunk_len], vec![0f32; self.chunk_len]);
            if let Some((mm, vv)) = moments {
                m[..hi - lo].copy_from_slice(&mm[lo..hi]);
                v[..hi - lo].copy_from_slice(&vv[lo..hi]);
            }
            let msg = InitChunk { chunk: c as u32, version, params: chunk, m, v };
            self.owner(c)
                .call(PS_INIT, &msg.to_bytes())
                .map_err(|e| anyhow!("init chunk {c}: {e}"))?;
        }
        Ok(())
    }

    /// Pull the full flat parameter vector at `min_version`.  Returns the
    /// (common) version and the assembled vector.
    pub fn pull(&self, min_version: u64) -> Result<(u64, Vec<f32>)> {
        self.pull_timeout(min_version, PULL_TIMEOUT_MS)
    }

    /// Like [`PsClient::pull`] with an explicit per-chunk wait budget, so
    /// callers can slice a barrier wait into interruptible pieces.
    pub fn pull_timeout(&self, min_version: u64, timeout_ms: u64) -> Result<(u64, Vec<f32>)> {
        let mut flat = vec![0f32; self.n_params];
        let mut version = u64::MAX;
        for c in 0..self.n_chunks() {
            let req = PullRequest {
                chunk: c as u32,
                min_version,
                timeout_ms,
            };
            let resp = self
                .owner(c)
                .call(PS_PULL, &req.to_bytes())
                .map_err(|e| anyhow!("pull chunk {c}: {e}"))?;
            let resp = PullResponse::from_bytes(&resp).context("decoding pull")?;
            let lo = c * self.chunk_len;
            let hi = ((c + 1) * self.chunk_len).min(self.n_params);
            flat[lo..hi].copy_from_slice(&resp.params[..hi - lo]);
            version = version.min(resp.version);
        }
        Ok((version, flat))
    }

    /// Push one step's gradient, sliced per chunk.  The request encoding
    /// is built once into a reused buffer per chunk (§Perf L3 pass 2: no
    /// per-chunk Vec churn on the hot path).  Returns the minimum chunk
    /// version observed after the push — a value *below* `step` means a
    /// relaunched PS rolled the parameters back and the worker must
    /// resync.
    pub fn push(
        &self,
        grads: &[f32],
        step: u64,
        worker: u32,
        n_workers: u32,
        lr: f32,
        mode: u8,
    ) -> Result<u64> {
        let mut chunk = vec![0f32; self.chunk_len];
        let mut buf = crate::net::wire::Writer::with_capacity(self.chunk_len * 4 + 32);
        let mut version = u64::MAX;
        for c in 0..self.n_chunks() {
            let lo = c * self.chunk_len;
            let hi = ((c + 1) * self.chunk_len).min(self.n_params);
            chunk[..hi - lo].copy_from_slice(&grads[lo..hi]);
            chunk[hi - lo..].fill(0.0);
            buf.buf.clear();
            buf.u32(c as u32);
            buf.u64(step);
            buf.u32(worker);
            buf.f32_slice(&chunk);
            buf.u32(n_workers);
            buf.f32(lr);
            buf.u8(mode);
            let resp = self
                .owner(c)
                .call(PS_PUSH, &buf.buf)
                .map_err(|e| anyhow!("push chunk {c}: {e}"))?;
            if let Ok(v) = u64::from_bytes(&resp) {
                version = version.min(v);
            }
        }
        Ok(if version == u64::MAX { step } else { version })
    }

    /// Fetch Adam moments for an exact checkpoint (chief only).
    pub fn moments(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut m = vec![0f32; self.n_params];
        let mut v = vec![0f32; self.n_params];
        for c in 0..self.n_chunks() {
            let resp = self
                .owner(c)
                .call(PS_MOMENTS, &(c as u32).to_bytes())
                .map_err(|e| anyhow!("moments chunk {c}: {e}"))?;
            let resp = MomentsResponse::from_bytes(&resp).context("decoding moments")?;
            let lo = c * self.chunk_len;
            let hi = ((c + 1) * self.chunk_len).min(self.n_params);
            m[lo..hi].copy_from_slice(&resp.m[..hi - lo]);
            v[lo..hi].copy_from_slice(&resp.v[..hi - lo]);
        }
        Ok((m, v))
    }

    pub fn stats(&self) -> Result<Vec<PsStats>> {
        self.clients
            .iter()
            .map(|c| {
                let b = c.call(PS_STATE, &[]).map_err(|e| anyhow!("stats: {e}"))?;
                PsStats::from_bytes(&b).map_err(|e| anyhow!("{e}"))
            })
            .collect()
    }
}

fn clip_grads(grads: &mut [f32], max_norm: f64) {
    if max_norm <= 0.0 {
        return;
    }
    let norm: f64 = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt();
    if norm > max_norm {
        let scale = (max_norm / norm) as f32;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
}

/// Is a patched spec waiting to be applied?
fn reconfig_pending(ctx: &WorkerContext) -> bool {
    ctx.reconfig
        .as_ref()
        .map(|cell| cell.lock().unwrap().is_some())
        .unwrap_or(false)
}

/// Drain the pending patched spec, if any.
fn take_reconfig(ctx: &WorkerContext) -> Option<ClusterSpec> {
    ctx.reconfig.as_ref().and_then(|cell| cell.lock().unwrap().take())
}

/// A PS interaction that may be interrupted by a pending reconfiguration.
enum PsOutcome<T> {
    Done(T),
    /// A patched spec is waiting; abandon the operation and let the step
    /// loop apply it.
    Reconfig,
}

/// Run a PS operation with transient-outage retries: a kill aborts, a
/// pending reconfiguration interrupts, and transport errors are retried
/// until `PULL_TIMEOUT_MS` elapses (a replacement PS is usually seconds
/// away, so dying on the first connection error would turn every PS
/// relaunch into a worker cascade).
fn ps_op<T>(
    ctx: &WorkerContext,
    step: u64,
    what: &str,
    mut op: impl FnMut() -> Result<T>,
) -> Result<PsOutcome<T>> {
    let deadline = Instant::now() + Duration::from_millis(PULL_TIMEOUT_MS);
    loop {
        if ctx.kill.load(Ordering::Relaxed) {
            bail!("worker:{} killed at step {step}", ctx.index);
        }
        if reconfig_pending(ctx) {
            return Ok(PsOutcome::Reconfig);
        }
        match op() {
            Ok(v) => return Ok(PsOutcome::Done(v)),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("{what} at step {step}"));
                }
                // Transport retry backoff (data plane, real time): a
                // replacement PS is seconds away, re-dial shortly.
                crate::util::clock::real_sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Chief-only: bring the parameter servers to a trainable state.  Warm
/// shards (all expected chunks present) are joined as-is — this is what
/// lets a *relaunched chief* join survivors without rolling anyone back.
/// If any shard is fresh (initial launch, or a PS that was surgically
/// relaunched and lost its in-memory state), every shard is re-seeded
/// from the latest checkpoint (or from `init_params` when none exists)
/// and a restore marker is recorded for the incarnation.
fn chief_init_ps(
    ctx: &WorkerContext,
    ps: &PsClient,
    store: &CheckpointStore,
    spec_version: u64,
) -> Result<u64> {
    if !ps.any_uninitialized()? {
        tdebug!("worker", "chief joining warm parameter servers (no re-init)");
        return Ok(0);
    }
    let restored = store.latest()?;
    let (params, moments, start) = match restored {
        Some(ckpt) => {
            tinfo!("worker", "chief restoring checkpoint at step {}", ckpt.step);
            (ckpt.params, ckpt.moments, ckpt.step)
        }
        None => {
            let out = ctx
                .engine
                .execute("init_params", vec![Tensor::scalar_u32(ctx.train.seed as u32)])
                .context("init_params")?;
            (out[0].as_f32().unwrap().to_vec(), None, 0)
        }
    };
    ps.init(&params, moments.as_ref(), start)?;
    store.mark_restore(spec_version, start)?;
    tinfo!(
        "worker",
        "chief initialized {} chunks at version {start} (spec v{spec_version})",
        ps.n_chunks()
    );
    Ok(start)
}

/// Worker task body.  Returns Ok(final_step) or an error (task failure —
/// the TaskExecutor reports it and the AM's fault-tolerance kicks in).
pub fn run_worker(ctx: &WorkerContext) -> Result<u64> {
    let meta = ctx.engine.meta().clone();
    let mode = if ctx.train.mode == "async" { MODE_ASYNC } else { MODE_SYNC };
    let mut ps = PsClient::connect(&ctx.ps_endpoints, meta.n_params, meta.chunk_len)?;
    let corpus = SyntheticCorpus::new(meta.dims.vocab, ctx.train.seed);
    let store = CheckpointStore::new(&ctx.train.checkpoint_dir);
    let is_chief = ctx.index == 0;
    let mut spec_version = ctx.spec_version;

    // ---- init / restore (chief) ----
    if is_chief {
        chief_init_ps(ctx, &ps, &store, spec_version)?;
    }

    // ---- resolve starting step (everyone) ----
    let (start_version, mut params) = ps.pull(0)?;
    let mut step = start_version;
    let target = ctx.train.steps;
    let mut step_ms_hist: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    tdebug!("worker", "worker:{} starting at step {step}/{target}", ctx.index);

    while step < target {
        if ctx.kill.load(Ordering::Relaxed) {
            bail!("worker:{} killed at step {step}", ctx.index);
        }
        // ---- apply a patched cluster spec (surgical recovery) ----
        if let Some(spec) = take_reconfig(ctx) {
            spec_version = spec.version;
            tinfo!(
                "worker",
                "worker:{} applying patched spec v{spec_version} at step {step}",
                ctx.index
            );
            ps = PsClient::connect(spec.endpoints(PS), meta.n_params, meta.chunk_len)?;
            if is_chief {
                chief_init_ps(ctx, &ps, &store, spec_version)?;
            }
            // Resync off the live parameter version: unchanged when only
            // workers were replaced, rolled back to the checkpoint when a
            // PS lost its state.
            let (v, p) = ps.pull(0)?;
            tdebug!("worker", "worker:{} resynced to step {v}", ctx.index);
            step = v;
            params = p;
            continue;
        }

        let iter_start = Instant::now();
        let tokens = corpus.batch(ctx.index, step, meta.dims.batch, meta.dims.seq_len);
        let batch = Tensor::i32(&[meta.dims.batch, meta.dims.seq_len + 1], tokens);
        // `params` is re-pulled after the push, so the engine can consume
        // this copy by move (§Perf L3 pass 2: -1 full-vector clone/step).
        let params_t = Tensor::f32(&[meta.n_params], std::mem::take(&mut params));
        let mut out = ctx
            .engine
            .execute("worker_step", vec![params_t, batch])
            .with_context(|| format!("worker_step at step {step}"))?;
        let loss = out[0].scalar().ok_or_else(|| anyhow!("loss not scalar"))?;
        if !loss.is_finite() {
            bail!("non-finite loss {loss} at step {step}");
        }
        let mut grads = out.pop().unwrap().into_f32().ok_or_else(|| anyhow!("bad grads"))?;
        clip_grads(&mut grads, ctx.train.grad_clip);

        // ---- push (transient PS outages retried, reconfig-aware) ----
        let seen = match ps_op(ctx, step, "push", || {
            ps.push(&grads, step, ctx.index, ctx.n_workers, ctx.train.lr as f32, mode)
        })? {
            PsOutcome::Done(v) => v,
            PsOutcome::Reconfig => continue, // outer loop applies the new spec
        };
        if mode == MODE_SYNC && seen < step {
            // A relaunched PS rolled the parameters back below our step:
            // resync instead of dying.
            let (v, p) = ps.pull(0)?;
            tdebug!("worker", "worker:{} rolled back {step} -> {v}; resyncing", ctx.index);
            step = v;
            params = p;
            // Drop loss-history entries beyond the rollback point so the
            // recorded curve stays step-sorted (the heartbeat delta
            // protocol depends on that) and the retrained steps replace
            // the stale tail instead of colliding with it.  Bumping
            // `history_rewound` tells the executor's heartbeat thread
            // its delivered watermark is void (it re-sends; the AM
            // splices).
            {
                let mut m = ctx.metrics.lock().unwrap();
                m.loss_history.retain(|&(s, _)| s <= v);
                m.history_rewound += 1;
            }
            continue;
        }

        // ---- pull: in sync mode this is the barrier for step+1 ----
        // Sliced so kills and reconfigurations interrupt it promptly.
        let next = if mode == MODE_SYNC { step + 1 } else { 0 };
        let (_v, new_params) =
            match ps_op(ctx, step, "barrier pull", || ps.pull_timeout(next, PULL_SLICE_MS))? {
                PsOutcome::Done(r) => r,
                PsOutcome::Reconfig => continue,
            };
        params = new_params;
        step += 1;

        let ms = iter_start.elapsed().as_secs_f64() * 1e3;
        step_ms_hist.push(ms);
        if step_ms_hist.len() > 50 {
            step_ms_hist.remove(0);
        }
        {
            let mut m = ctx.metrics.lock().unwrap();
            m.step = step;
            m.loss = loss;
            m.tokens_done += meta.tokens_per_step() as u64;
            m.step_ms_avg = step_ms_hist.iter().sum::<f64>() / step_ms_hist.len() as f64;
            m.mem_used_mb = ((meta.n_params * 8 + meta.tokens_per_step() * 4) >> 20) as u64;
            if step % 5 == 0 || step == target {
                m.loss_history.push((step, loss));
                if m.loss_history.len() > ctx.loss_history_cap.max(1) {
                    // Chunked front-drain, amortized O(1) per entry
                    // (same scheme as the AM-side fold).
                    let cap = ctx.loss_history_cap.max(1);
                    let excess = m.loss_history.len() - cap;
                    let n = excess.max(cap / 4).min(m.loss_history.len());
                    m.loss_history.drain(..n);
                }
            }
        }

        if is_chief {
            if ctx.train.checkpoint_every > 0 && step % ctx.train.checkpoint_every == 0 {
                let (m, v) = ps.moments()?;
                store.save(&Checkpoint { step, params: params.clone(), moments: Some((m, v)) })?;
                tdebug!("worker", "chief checkpointed at step {step}");
            }
            if ctx.train.eval_every > 0 && step % ctx.train.eval_every == 0 {
                let tokens =
                    corpus.batch(10_000 + ctx.index, step, meta.dims.batch, meta.dims.seq_len);
                let batch = Tensor::i32(&[meta.dims.batch, meta.dims.seq_len + 1], tokens);
                let out = ctx
                    .engine
                    .execute(
                        "eval_loss",
                        vec![Tensor::f32(&[meta.n_params], params.clone()), batch],
                    )
                    .context("eval_loss")?;
                let ev = out[0].scalar().unwrap_or(f32::NAN);
                ctx.metrics.lock().unwrap().eval_loss = ev;
                tinfo!("worker", "eval at step {step}: loss={ev:.4}");
            }
        }
    }

    // Final checkpoint so the next attempt (or a resumed job) starts here.
    if is_chief && ctx.train.checkpoint_every > 0 {
        let (m, v) = ps.moments()?;
        store.save(&Checkpoint { step, params, moments: Some((m, v)) })?;
    }
    {
        let mut m = ctx.metrics.lock().unwrap();
        m.finished = true;
        m.step = step;
    }
    let dt = t0.elapsed().as_secs_f64();
    tinfo!(
        "worker",
        "worker:{} done: {} steps in {dt:.1}s ({:.1} steps/s)",
        ctx.index,
        step.saturating_sub(start_version),
        step.saturating_sub(start_version) as f64 / dt.max(1e-9)
    );
    Ok(step)
}

/// Worker task main: adapts `run_worker` to the container exit-code
/// convention.
pub fn worker_main(ctx: WorkerContext) -> i32 {
    match run_worker(&ctx) {
        Ok(_) => 0,
        Err(e) => {
            crate::terror!("worker", "worker:{} failed: {e:#}", ctx.index);
            if ctx.kill.load(Ordering::Relaxed) {
                // Killed by the framework: report "killed", not "failed".
                143
            } else {
                1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_grads_caps_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        clip_grads(&mut g, 1.0);
        let norm: f32 = g.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        // No-op cases.
        let mut g2 = vec![0.1f32, 0.1];
        clip_grads(&mut g2, 10.0);
        assert_eq!(g2, vec![0.1, 0.1]);
        let mut g3 = vec![3.0f32];
        clip_grads(&mut g3, 0.0);
        assert_eq!(g3, vec![3.0]);
    }
}
