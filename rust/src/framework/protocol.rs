//! Wire messages for the PS <-> worker protocol + the TF_CONFIG-style
//! cluster spec, and the metrics block tasks report to their executor.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::json::Json;
use crate::net::wire::{Reader, Wire, WireError, Writer};
use crate::util::HostPort;

// ---- PS RPC method ids ----
pub const PS_INIT: u16 = 1;
pub const PS_PULL: u16 = 2;
pub const PS_PUSH: u16 = 3;
pub const PS_STATE: u16 = 4;
pub const PS_MOMENTS: u16 = 5;

/// Training modes.
pub const MODE_SYNC: u8 = 0;
pub const MODE_ASYNC: u8 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct InitChunk {
    pub chunk: u32,
    /// Version to seed (the restore step; 0 for fresh init).
    pub version: u64,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Wire for InitChunk {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.chunk);
        w.u64(self.version);
        w.f32_slice(&self.params);
        w.f32_slice(&self.m);
        w.f32_slice(&self.v);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(InitChunk {
            chunk: r.u32()?,
            version: r.u64()?,
            params: r.f32_vec()?,
            m: r.f32_vec()?,
            v: r.f32_vec()?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct PullRequest {
    pub chunk: u32,
    /// Block until the chunk reaches at least this version.
    pub min_version: u64,
    pub timeout_ms: u64,
}

impl Wire for PullRequest {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.chunk);
        w.u64(self.min_version);
        w.u64(self.timeout_ms);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(PullRequest { chunk: r.u32()?, min_version: r.u64()?, timeout_ms: r.u64()? })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct PullResponse {
    pub version: u64,
    pub params: Vec<f32>,
}

impl Wire for PullResponse {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.version);
        w.f32_slice(&self.params);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(PullResponse { version: r.u64()?, params: r.f32_vec()? })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct PushRequest {
    pub chunk: u32,
    /// The parameter version the gradient was computed against.
    pub step: u64,
    /// Index of the pushing worker.  The sync barrier counts *distinct*
    /// contributors, so a relaunched worker re-pushing a step its dead
    /// incarnation already delivered is a no-op instead of a double count.
    pub worker: u32,
    pub grads: Vec<f32>,
    pub n_workers: u32,
    pub lr: f32,
    pub mode: u8,
}

impl Wire for PushRequest {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.chunk);
        w.u64(self.step);
        w.u32(self.worker);
        w.f32_slice(&self.grads);
        w.u32(self.n_workers);
        w.f32(self.lr);
        w.u8(self.mode);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(PushRequest {
            chunk: r.u32()?,
            step: r.u64()?,
            worker: r.u32()?,
            grads: r.f32_vec()?,
            n_workers: r.u32()?,
            lr: r.f32()?,
            mode: r.u8()?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct MomentsResponse {
    pub version: u64,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

impl Wire for MomentsResponse {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.version);
        w.f32_slice(&self.m);
        w.f32_slice(&self.v);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(MomentsResponse { version: r.u64()?, m: r.f32_vec()?, v: r.f32_vec()? })
    }
}

/// PS shard statistics (PS_STATE) — consumed by monitoring/Dr. Elephant.
#[derive(Debug, Clone, PartialEq)]
pub struct PsStats {
    pub owned_chunks: u32,
    pub min_version: u64,
    pub applied_updates: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl Wire for PsStats {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.owned_chunks);
        w.u64(self.min_version);
        w.u64(self.applied_updates);
        w.u64(self.bytes_in);
        w.u64(self.bytes_out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(PsStats {
            owned_chunks: r.u32()?,
            min_version: r.u64()?,
            applied_updates: r.u64()?,
            bytes_in: r.u64()?,
            bytes_out: r.u64()?,
        })
    }
}

// ---------------------------------------------------------------------
// Cluster spec (the TF_CONFIG analogue)
// ---------------------------------------------------------------------

/// The global cluster spec the AM assembles from TaskExecutor
/// registrations and broadcasts back (paper §2.2).  JSON shape mirrors
/// TF_CONFIG: `{"cluster": {"worker": ["h:p", ...], "ps": [...]},
/// "task": {"type": "worker", "index": 0}, "version": 2}`.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// job type -> endpoints ordered by task index.
    pub tasks: BTreeMap<String, Vec<HostPort>>,
    /// Bumped on every AM rebuild (task relaunch) so stale tasks notice.
    pub version: u64,
}

impl ClusterSpec {
    pub fn new(version: u64) -> ClusterSpec {
        ClusterSpec { tasks: BTreeMap::new(), version }
    }

    pub fn endpoints(&self, job_type: &str) -> &[HostPort] {
        self.tasks.get(job_type).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.values().map(|v| v.len()).sum()
    }

    /// Render as TF_CONFIG-style JSON for one task's env.
    pub fn to_tf_config(&self, task_type: &str, index: u32) -> String {
        let mut cluster = Json::obj();
        for (ty, eps) in &self.tasks {
            cluster.set(
                ty,
                Json::Arr(eps.iter().map(|e| Json::Str(e.to_string())).collect()),
            );
        }
        let mut task = Json::obj();
        task.set("type", task_type).set("index", index as u64);
        let mut root = Json::obj();
        root.set("cluster", cluster).set("task", task).set("version", self.version);
        root.render()
    }

    pub fn from_tf_config(s: &str) -> Result<(ClusterSpec, String, u32)> {
        let j = Json::parse(s).map_err(|e| anyhow!("bad TF_CONFIG: {e}"))?;
        let mut spec = ClusterSpec::new(j.get("version").and_then(|v| v.as_u64()).unwrap_or(0));
        let cluster = j
            .get("cluster")
            .and_then(|c| c.as_obj())
            .ok_or_else(|| anyhow!("TF_CONFIG missing cluster"))?;
        for (ty, eps) in cluster {
            let list = eps
                .as_arr()
                .ok_or_else(|| anyhow!("cluster.{ty} must be array"))?
                .iter()
                .map(|e| {
                    e.as_str()
                        .and_then(HostPort::parse)
                        .ok_or_else(|| anyhow!("bad endpoint in cluster.{ty}"))
                })
                .collect::<Result<Vec<_>>>()?;
            spec.tasks.insert(ty.clone(), list);
        }
        let ty = j
            .at(&["task", "type"])
            .and_then(|t| t.as_str())
            .ok_or_else(|| anyhow!("TF_CONFIG missing task.type"))?
            .to_string();
        let index = j
            .at(&["task", "index"])
            .and_then(|i| i.as_u64())
            .ok_or_else(|| anyhow!("TF_CONFIG missing task.index"))? as u32;
        Ok((spec, ty, index))
    }
}

// ---------------------------------------------------------------------
// Task metrics (task -> executor -> AM heartbeats -> portal/Dr. Elephant)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Default, PartialEq)]
pub struct TaskMetrics {
    pub step: u64,
    pub loss: f32,
    pub eval_loss: f32,
    pub tokens_done: u64,
    pub step_ms_avg: f64,
    /// Estimated working-set (params + moments + buffers), MB.
    pub mem_used_mb: u64,
    pub updates_applied: u64,
    pub finished: bool,
    pub loss_history: Vec<(u64, f32)>,
    /// Count of local loss-history truncations (sync rollbacks).  The
    /// executor's heartbeat thread watches this to know its delivered
    /// watermark is void and the history must be re-sent for the AM to
    /// splice — a flag-free check like "last step < watermark" races
    /// with retraining that re-reaches the watermark between beats.
    pub history_rewound: u64,
}

impl TaskMetrics {
    /// Copy without the loss history — O(1) however long training ran.
    pub fn scalars(&self) -> TaskMetrics {
        TaskMetrics {
            step: self.step,
            loss: self.loss,
            eval_loss: self.eval_loss,
            tokens_done: self.tokens_done,
            step_ms_avg: self.step_ms_avg,
            mem_used_mb: self.mem_used_mb,
            updates_applied: self.updates_applied,
            finished: self.finished,
            loss_history: Vec::new(),
            history_rewound: self.history_rewound,
        }
    }

    /// Copy carrying only the loss-history entries with step > `from`:
    /// the *incremental delta* a heartbeat ships.  The executor tracks
    /// the newest step it successfully delivered and the AM re-assembles
    /// the full curve, so the heartbeat hot path stays O(1) in wire size
    /// instead of re-serializing the whole history every beat.  Assumes
    /// `loss_history` is step-ordered (tasks append monotonically).
    pub fn delta_since(&self, from: Option<u64>) -> TaskMetrics {
        let mut m = self.scalars();
        let start = match from {
            None => 0,
            Some(f) => self.loss_history.partition_point(|&(s, _)| s <= f),
        };
        m.loss_history.extend_from_slice(&self.loss_history[start..]);
        m
    }

    /// Newest loss-history step, if any.
    pub fn last_history_step(&self) -> Option<u64> {
        self.loss_history.last().map(|&(s, _)| s)
    }
}

impl Wire for TaskMetrics {
    fn encode(&self, w: &mut Writer) {
        w.u64(self.step);
        w.f32(self.loss);
        w.f32(self.eval_loss);
        w.u64(self.tokens_done);
        w.f64(self.step_ms_avg);
        w.u64(self.mem_used_mb);
        w.u64(self.updates_applied);
        w.bool(self.finished);
        w.u32(self.loss_history.len() as u32);
        for (s, l) in &self.loss_history {
            w.u64(*s);
            w.f32(*l);
        }
        w.u64(self.history_rewound);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let mut m = TaskMetrics {
            step: r.u64()?,
            loss: r.f32()?,
            eval_loss: r.f32()?,
            tokens_done: r.u64()?,
            step_ms_avg: r.f64()?,
            mem_used_mb: r.u64()?,
            updates_applied: r.u64()?,
            finished: r.bool()?,
            loss_history: Vec::new(),
            history_rewound: 0,
        };
        let n = r.u32()? as usize;
        let keep = n.min(1 << 20);
        for _ in 0..keep {
            m.loss_history.push((r.u64()?, r.f32()?));
        }
        // Entries past the decode cap must still be consumed, or the
        // trailing field below would read from the middle of one.
        for _ in keep..n {
            let _ = r.u64()?;
            let _ = r.f32()?;
        }
        m.history_rewound = r.u64()?;
        Ok(m)
    }
}

/// Shared metrics cell between a task thread and its TaskExecutor.
pub type MetricsCell = Arc<Mutex<TaskMetrics>>;

pub fn new_metrics_cell() -> MetricsCell {
    Arc::new(Mutex::new(TaskMetrics::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_round_trip() {
        let init = InitChunk {
            chunk: 3,
            version: 10,
            params: vec![1.0, 2.0],
            m: vec![0.0; 2],
            v: vec![0.5; 2],
        };
        assert_eq!(InitChunk::from_bytes(&init.to_bytes()).unwrap(), init);

        let push = PushRequest {
            chunk: 1,
            step: 9,
            worker: 2,
            grads: vec![0.25; 8],
            n_workers: 4,
            lr: 1e-3,
            mode: MODE_SYNC,
        };
        assert_eq!(PushRequest::from_bytes(&push.to_bytes()).unwrap(), push);

        let pull = PullRequest { chunk: 0, min_version: 7, timeout_ms: 100 };
        assert_eq!(PullRequest::from_bytes(&pull.to_bytes()).unwrap(), pull);

        let stats = PsStats {
            owned_chunks: 2,
            min_version: 5,
            applied_updates: 10,
            bytes_in: 100,
            bytes_out: 200,
        };
        assert_eq!(PsStats::from_bytes(&stats.to_bytes()).unwrap(), stats);
    }

    #[test]
    fn tf_config_round_trip() {
        let mut spec = ClusterSpec::new(2);
        spec.tasks.insert(
            "worker".into(),
            vec![HostPort::localhost(5000), HostPort::localhost(5001)],
        );
        spec.tasks.insert("ps".into(), vec![HostPort::localhost(6000)]);
        let s = spec.to_tf_config("worker", 1);
        let (parsed, ty, idx) = ClusterSpec::from_tf_config(&s).unwrap();
        assert_eq!(parsed, spec);
        assert_eq!(ty, "worker");
        assert_eq!(idx, 1);
        assert_eq!(parsed.endpoints("ps").len(), 1);
        assert_eq!(parsed.n_tasks(), 3);
    }

    #[test]
    fn tf_config_errors() {
        assert!(ClusterSpec::from_tf_config("{}").is_err());
        assert!(ClusterSpec::from_tf_config("not json").is_err());
        let missing_task = r#"{"cluster": {"worker": ["127.0.0.1:1"]}}"#;
        assert!(ClusterSpec::from_tf_config(missing_task).is_err());
    }

    #[test]
    fn metrics_round_trip() {
        let m = TaskMetrics {
            step: 100,
            loss: 2.5,
            eval_loss: 2.4,
            tokens_done: 25_600,
            step_ms_avg: 12.5,
            mem_used_mb: 64,
            updates_applied: 0,
            finished: true,
            loss_history: vec![(1, 5.5), (50, 3.0), (100, 2.5)],
            history_rewound: 2,
        };
        assert_eq!(TaskMetrics::from_bytes(&m.to_bytes()).unwrap(), m);
    }
}
