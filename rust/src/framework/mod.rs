//! The distributed training framework TonY orchestrates — the role
//! TensorFlow's PS/worker runtime plays in the paper (§2.2: "Once all the
//! ML jobs start up, they will communicate and coordinate with one another
//! via the ML framework's distributed protocol").
//!
//! Architecture: synchronous (or async) data-parallel training with
//! parameter servers.
//!
//! - The flat f32[N] parameter vector (layout fixed by
//!   python/compile/model.py::param_specs) is split into fixed-size chunks
//!   (`meta.chunk_len`, zero-padded tail); chunk `c` lives on PS shard
//!   `c % n_ps`.
//! - Workers pull all chunks at version `t`, run the AOT `worker_step`
//!   executable (loss + grads) via PJRT, and push per-chunk gradient
//!   slices tagged `t`.
//! - In sync mode each PS shard averages the `W` worker gradients for a
//!   chunk, applies the AOT fused-Adam `ps_adam` executable, and bumps the
//!   chunk to version `t+1`; pulls for `t+1` block until then.  In async
//!   mode pushes apply immediately (hogwild-style).
//! - worker:0 is the chief: it initializes (or restores) parameters,
//!   checkpoints every `k` steps (with exact Adam moments), and runs
//!   periodic evals.
//!
//! Everything crosses real TCP via `crate::net::rpc`, so the cluster spec
//! the AM distributes is load-bearing exactly as in the paper.

pub mod evaluator;
pub mod protocol;
pub mod ps;
pub mod worker;

pub use protocol::{ClusterSpec, TaskMetrics};
pub use evaluator::evaluator_main;
pub use ps::{ps_main, PsServer};
pub use worker::{worker_main, WorkerContext};
