//! Shared AM state: task registry, cluster-spec assembly, heartbeat
//! liveness, and the RPC handler the TaskExecutors talk to.  The portal
//! reads snapshots of this concurrently.
//!
//! Versioning model: the *cluster-spec version* is a monotonic counter
//! bumped on every full attempt **and** on every surgical recovery.  Each
//! task record remembers the version its current incarnation was launched
//! at (`spec_version`) plus the last version its executor heartbeated
//! with (`acked_version`).  A heartbeat older than the record's launch
//! version is a zombie from a replaced incarnation (Abort); a heartbeat
//! older than the cluster version from a live incarnation is a survivor
//! that needs the patched spec (Reconfigure).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::framework::protocol::{ClusterSpec, TaskMetrics};
use crate::json::Json;
use crate::metrics::Registry;
use crate::net::rpc::RpcHandler;
use crate::net::wire::Wire;
use crate::tonyconf::JobSpec;
use crate::trace::{SpanStore, Stage};
use crate::util::clock::{Clock, SystemClock};
use crate::util::event::{tag, WakeupBus};
use crate::util::ids::{ContainerId, TaskId};
use crate::util::HostPort;

use super::protocol::*;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    Negotiating,
    Running,
    /// Surgical recovery in flight: replacements are being relaunched
    /// while the surviving containers keep running.
    Recovering,
    /// Full teardown + relaunch of the whole attempt (escalation path).
    Restarting,
    Succeeded,
    Failed,
}

#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    pub container: Option<ContainerId>,
    pub endpoint: Option<HostPort>,
    pub ui_url: Option<String>,
    /// Clock time (ms) of the last sign of life: launch, registration,
    /// or heartbeat.  Clock-based (not `Instant`) so liveness expiry is
    /// drivable by a manual clock in tests.
    pub last_heartbeat: Option<u64>,
    pub metrics: TaskMetrics,
    pub exit_code: Option<i64>,
    pub command: AmCommand,
    /// Cluster-spec version this incarnation was launched at.
    pub spec_version: u32,
    /// Last cluster-spec version the executor heartbeated/registered
    /// with — the "spec applied" ack used by the recovery barrier.
    pub acked_version: u32,
    /// How many times this task has been (re)launched within the current
    /// attempt (0 = original launch).
    pub generation: u32,
}

impl TaskRecord {
    fn new(task: TaskId, spec_version: u32) -> TaskRecord {
        TaskRecord {
            task,
            container: None,
            endpoint: None,
            ui_url: None,
            last_heartbeat: None,
            metrics: TaskMetrics::default(),
            exit_code: None,
            command: AmCommand::None,
            spec_version,
            acked_version: 0,
            generation: 0,
        }
    }
}

#[derive(Debug)]
struct Inner {
    attempt: u32,
    /// Monotonic cluster-spec version (never reused across attempts or
    /// recoveries, so zombie detection stays exact).
    version: u32,
    phase: JobPhase,
    tasks: BTreeMap<TaskId, TaskRecord>,
    expected: Vec<TaskId>,
    spec: Option<ClusterSpec>,
    started_at_ms: u64,
    /// Surgical recoveries performed over the job's lifetime.
    recoveries: u32,
    /// Grants released back to the RM because they matched no task
    /// (unknown priority or surplus) — diagnostic for the leak fix.
    released_grants: u64,
    /// Containers this job lost to capacity preemption (`Preempted`
    /// exits absorbed by surgical recovery).
    preempted: u64,
    /// Cluster-spec fetches served at the current version; when every
    /// expected task has fetched, the spec-sync stage is over.
    spec_fetches: usize,
    /// Elastic grow waves performed over the job's lifetime.
    grows: u32,
    /// Elastic shrink waves performed over the job's lifetime.
    shrinks: u32,
}

/// The outcome of one attempt, as decided by the AM monitor loop.
#[derive(Debug, Clone, PartialEq)]
pub enum AttemptOutcome {
    Succeeded,
    TaskFailed(String),
    AmKilled,
}

pub struct AmState {
    inner: Mutex<Inner>,
    /// The AM's wakeup bus.  The monitor loop is its single draining
    /// consumer; the RM's grant/completion notifications, the RPC
    /// handler's registration/ack/exit notifications, and the container
    /// kill switch all land here.  Spec long-polls ride its sequence
    /// (non-draining).
    bus: Arc<WakeupBus>,
    clock: Arc<dyn Clock>,
    expected_from: Box<dyn Fn(u32) -> Vec<TaskId> + Send + Sync>,
    /// The job this AM is running (immutable; read by the portal for
    /// streaming Dr. Elephant analysis).
    job: JobSpec,
    /// Live time-series registry heartbeats fold into (see
    /// [`crate::metrics`]); read concurrently by the portal/gateway.
    registry: Arc<Registry>,
    /// Bound on the accumulated per-task loss history (the heartbeat
    /// protocol ships deltas; the AM owns the full curve).
    loss_history_cap: usize,
    /// Monitor-loop iterations — the idle-CPU proxy `bench_latency`
    /// reports (event-driven loops should iterate per *event*, not per
    /// poll interval).
    loop_iters: AtomicU64,
    /// The job's lifecycle span store, installed once at submit.  Stage
    /// transitions (scheduling → launching → registering → spec-sync →
    /// running) are recorded where the state machine itself moves.
    trace: std::sync::OnceLock<Arc<SpanStore>>,
}

impl AmState {
    pub fn new(job: &JobSpec) -> AmState {
        Self::with_clock(job, SystemClock::shared())
    }

    pub fn with_clock(job: &JobSpec, clock: Arc<dyn Clock>) -> AmState {
        let types: Vec<(String, u32)> = job
            .task_types
            .iter()
            .map(|t| (t.name.clone(), t.instances))
            .collect();
        let expected_from = Box::new(move |_attempt: u32| {
            let mut out = Vec::new();
            for (ty, n) in &types {
                for i in 0..*n {
                    out.push(TaskId::new(ty.clone(), i));
                }
            }
            out
        });
        let bus = WakeupBus::for_clock(&clock);
        AmState {
            inner: Mutex::new(Inner {
                attempt: 0,
                version: 0,
                phase: JobPhase::Negotiating,
                tasks: BTreeMap::new(),
                expected: Vec::new(),
                spec: None,
                started_at_ms: clock.now_ms(),
                recoveries: 0,
                released_grants: 0,
                preempted: 0,
                spec_fetches: 0,
                grows: 0,
                shrinks: 0,
            }),
            bus,
            clock,
            expected_from,
            registry: Arc::new(Registry::new(
                job.metrics.retention_points,
                job.metrics.sample_interval_ms,
            )),
            loss_history_cap: job.metrics.loss_history_cap(),
            job: job.clone(),
            loop_iters: AtomicU64::new(0),
            trace: std::sync::OnceLock::new(),
        }
    }

    /// Install the job's lifecycle span store (done once, at submit,
    /// before the AM launchable is released).
    pub fn set_trace(&self, store: &Arc<SpanStore>) {
        let _ = self.trace.set(store.clone());
    }

    /// The job's span store, when one was installed (portal/gateway
    /// exposition and the stage hooks below).
    pub fn trace(&self) -> Option<&Arc<SpanStore>> {
        self.trace.get()
    }

    /// The AM's wakeup bus (see the field doc for the producer set).
    pub fn events(&self) -> &Arc<WakeupBus> {
        &self.bus
    }

    /// The clock all AM deadlines run on (shared with the RM).
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Count one monitor-loop pass (idle-CPU proxy for `bench_latency`).
    pub fn note_loop_iter(&self) {
        self.loop_iters.fetch_add(1, Ordering::Relaxed);
    }

    pub fn loop_iters(&self) -> u64 {
        self.loop_iters.load(Ordering::Relaxed)
    }

    /// The live metrics registry (portal `/metrics`, gateway aggregation,
    /// history persistence).
    pub fn metrics_registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The job spec this AM runs (streaming Dr. Elephant analysis needs
    /// the requested resources + checkpoint settings).
    pub fn job_spec(&self) -> &JobSpec {
        &self.job
    }

    /// True when `task` (as `type:index`) is one of the job's tasks.
    pub fn has_task(&self, task: &str) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.tasks.keys().any(|t| t.to_string() == task)
    }

    /// Latest metrics snapshot per task, without the loss history (the
    /// scalar view the `/metrics` gauges and streaming analysis read).
    pub fn task_metrics(&self) -> Vec<(String, TaskMetrics)> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .map(|r| (r.task.to_string(), r.metrics.scalars()))
            .collect()
    }

    pub fn begin_attempt(&self, attempt: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.attempt = attempt;
        inner.version += 1;
        inner.phase = JobPhase::Negotiating;
        inner.spec = None;
        inner.spec_fetches = 0;
        inner.expected = (self.expected_from)(attempt);
        let version = inner.version;
        inner.tasks = inner
            .expected
            .iter()
            .map(|t| (t.clone(), TaskRecord::new(t.clone(), version)))
            .collect();
        drop(inner);
        if let Some(t) = self.trace() {
            t.set_attempt(attempt);
            // A restart closes the previous attempt's open stages; the
            // first attempt ends the gateway's queued stage (no-ops when
            // those stages are not open).
            t.end_stage(Stage::Queued);
            t.end_stage(Stage::Running);
            t.start_stage(Stage::Scheduling);
        }
        self.bus.notify(tag::STATE);
    }

    /// Start a surgical recovery: bump the spec version, reset the dead
    /// tasks' records for relaunch, and invalidate the spec.  Surviving
    /// records keep their container, endpoint, and metrics.  Returns the
    /// new cluster-spec version the replacements must launch at.
    pub fn begin_recovery(&self, dead: &[TaskId]) -> u32 {
        let mut inner = self.inner.lock().unwrap();
        inner.version += 1;
        inner.spec = None;
        inner.spec_fetches = 0;
        inner.phase = JobPhase::Recovering;
        inner.recoveries += 1;
        let version = inner.version;
        let now = self.clock.now_ms();
        for t in dead {
            if let Some(r) = inner.tasks.get_mut(t) {
                r.container = None;
                r.endpoint = None;
                r.exit_code = None;
                r.metrics.finished = false;
                // Relaunch grace: the clock restarts so the liveness
                // checks measure the replacement, not the corpse.
                r.last_heartbeat = Some(now);
                r.generation += 1;
                r.spec_version = version;
                r.acked_version = 0;
            }
        }
        drop(inner);
        if let Some(t) = self.trace() {
            let dead_list =
                dead.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
            t.event(
                Stage::Running,
                "recovery",
                t.stage_span(Stage::Running),
                &[("dead", dead_list), ("version", version.to_string())],
            );
        }
        self.bus.notify(tag::STATE);
        version
    }

    /// Current worker count: how many `worker` tasks the job expects.
    pub fn expected_workers(&self) -> u32 {
        let inner = self.inner.lock().unwrap();
        inner.expected.iter().filter(|t| t.job_type == crate::tonyconf::WORKER).count() as u32
    }

    /// Start an elastic *grow* wave: splice `new_tasks` into the
    /// expected set with fresh records at a bumped spec version.  This
    /// reuses the surgical-recovery machinery end to end — the spec is
    /// invalidated, the phase moves to `Recovering`, and the wave is
    /// over when the recruits register and every survivor acks the new
    /// version (`recovery_complete`).  Returns the version the recruits
    /// must launch at.
    pub fn begin_grow(&self, new_tasks: &[TaskId]) -> u32 {
        let mut inner = self.inner.lock().unwrap();
        inner.version += 1;
        inner.spec = None;
        inner.spec_fetches = 0;
        inner.phase = JobPhase::Recovering;
        inner.grows += 1;
        let version = inner.version;
        let now = self.clock.now_ms();
        for t in new_tasks {
            inner.expected.push(t.clone());
            let mut rec = TaskRecord::new(t.clone(), version);
            // Launch grace starts now, same as a recovery relaunch.
            rec.last_heartbeat = Some(now);
            inner.tasks.insert(t.clone(), rec);
        }
        drop(inner);
        if let Some(t) = self.trace() {
            let list =
                new_tasks.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
            t.event(
                Stage::Running,
                "resize",
                t.stage_span(Stage::Running),
                &[
                    ("mode", "grow".to_string()),
                    ("new", list),
                    ("version", version.to_string()),
                ],
            );
        }
        self.bus.notify(tag::STATE);
        version
    }

    /// Start an elastic *shrink* wave: remove the `n` highest-index
    /// workers from the expected set and the registry (never `worker:0`,
    /// the chief), bump the spec version, and invalidate the spec.
    /// Returns the new version plus the removed `(task, container)`
    /// pairs so the AM can hand the containers back to the RM as
    /// cooperative releases (`ExitStatus::Released` — no restart-budget
    /// burn).  With the records gone, the removed tasks' completions are
    /// ignored, their zombie heartbeats get `Abort`, and survivors
    /// resync via `Reconfigure` once the contracted spec rebuilds.
    pub fn begin_shrink(&self, n: u32) -> (u32, Vec<(TaskId, Option<ContainerId>)>) {
        let mut inner = self.inner.lock().unwrap();
        let mut workers: Vec<TaskId> = inner
            .expected
            .iter()
            .filter(|t| t.job_type == crate::tonyconf::WORKER)
            .cloned()
            .collect();
        workers.sort_by_key(|t| t.index);
        // Defensive floor: keep at least one worker no matter what the
        // caller asked for (workers_min >= 1 enforces this upstream).
        let n = (n as usize).min(workers.len().saturating_sub(1));
        let doomed: Vec<TaskId> = workers.split_off(workers.len() - n);
        inner.version += 1;
        inner.spec = None;
        inner.spec_fetches = 0;
        inner.phase = JobPhase::Recovering;
        inner.shrinks += 1;
        let version = inner.version;
        let mut removed = Vec::with_capacity(doomed.len());
        for t in &doomed {
            inner.expected.retain(|e| e != t);
            let container = inner.tasks.remove(t).and_then(|r| r.container);
            removed.push((t.clone(), container));
        }
        drop(inner);
        if let Some(t) = self.trace() {
            let list = doomed.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
            t.event(
                Stage::Running,
                "resize",
                t.stage_span(Stage::Running),
                &[
                    ("mode", "shrink".to_string()),
                    ("released", list),
                    ("version", version.to_string()),
                ],
            );
        }
        self.bus.notify(tag::STATE);
        (version, removed)
    }

    /// Elastic grow waves performed so far (job lifetime).
    pub fn grows(&self) -> u32 {
        self.inner.lock().unwrap().grows
    }

    /// Elastic shrink waves performed so far (job lifetime).
    pub fn shrinks(&self) -> u32 {
        self.inner.lock().unwrap().shrinks
    }

    pub fn set_phase(&self, phase: JobPhase) {
        self.inner.lock().unwrap().phase = phase;
        self.bus.notify(tag::STATE);
    }

    pub fn phase(&self) -> JobPhase {
        self.inner.lock().unwrap().phase
    }

    pub fn attempt(&self) -> u32 {
        self.inner.lock().unwrap().attempt
    }

    /// Current cluster-spec version (monotonic across attempts and
    /// surgical recoveries).
    pub fn spec_version(&self) -> u32 {
        self.inner.lock().unwrap().version
    }

    /// Surgical recoveries performed so far (job lifetime).
    pub fn recoveries(&self) -> u32 {
        self.inner.lock().unwrap().recoveries
    }

    /// Containers released because their grant matched no task (see the
    /// unknown-grant leak fix in `am::run_attempt`).
    pub fn released_grants(&self) -> u64 {
        self.inner.lock().unwrap().released_grants
    }

    pub fn note_released_grants(&self, n: u64) {
        self.inner.lock().unwrap().released_grants += n;
    }

    /// Containers lost to capacity preemption over the job's lifetime.
    pub fn preempted(&self) -> u64 {
        self.inner.lock().unwrap().preempted
    }

    pub fn note_preempted(&self) {
        self.inner.lock().unwrap().preempted += 1;
    }

    pub fn record_launch(&self, task: TaskId, container: ContainerId) {
        let mut inner = self.inner.lock().unwrap();
        let version = inner.version;
        let rec = inner
            .tasks
            .entry(task.clone())
            .or_insert_with(|| TaskRecord::new(task.clone(), version));
        rec.container = Some(container);
        rec.spec_version = version;
        rec.last_heartbeat = Some(self.clock.now_ms()); // launch counts as life
        let all_launched = !inner.expected.is_empty()
            && inner.expected.iter().all(|t| {
                inner.tasks.get(t).map(|r| r.container.is_some()).unwrap_or(false)
            });
        drop(inner);
        if let Some(t) = self.trace() {
            // First launch flips scheduling → launching (the relaunches
            // of a surgical recovery find scheduling closed — no-op).
            t.end_stage(Stage::Scheduling);
            let parent = t.start_stage(Stage::Launching);
            t.event(
                Stage::Launching,
                &format!("launch {task}"),
                Some(parent).filter(|id| *id != 0),
                &[("container", container.to_string())],
            );
            if all_launched {
                t.end_stage(Stage::Launching);
                t.start_stage(Stage::Registering);
            }
        }
    }

    pub fn task_for_container(&self, container: ContainerId) -> Option<TaskId> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .find(|r| r.container == Some(container))
            .map(|r| r.task.clone())
    }

    pub fn forget_container(&self, container: ContainerId) {
        let mut inner = self.inner.lock().unwrap();
        for r in inner.tasks.values_mut() {
            if r.container == Some(container) {
                r.container = None;
            }
        }
    }

    pub fn live_containers(&self) -> Vec<ContainerId> {
        let inner = self.inner.lock().unwrap();
        inner.tasks.values().filter_map(|r| r.container).collect()
    }

    /// The container currently hosting `task`, if it is still live
    /// (chaos-injection targeting).
    pub fn live_containers_for(&self, task: &TaskId) -> Option<ContainerId> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .get(task)
            .filter(|r| r.exit_code.is_none())
            .and_then(|r| r.container)
    }

    /// The container recorded for `task`, dead or alive — the recovery
    /// path uses this to stop a failed task's old container.
    pub fn container_of(&self, task: &TaskId) -> Option<ContainerId> {
        let inner = self.inner.lock().unwrap();
        inner.tasks.get(task).and_then(|r| r.container)
    }

    /// Snapshot of every task's current container — benches and tests use
    /// this to prove survivors kept their containers across a recovery.
    pub fn container_map(&self) -> BTreeMap<TaskId, Option<ContainerId>> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .map(|r| (r.task.clone(), r.container))
            .collect()
    }

    pub fn task_exit(&self, task: &TaskId) -> Option<i64> {
        let inner = self.inner.lock().unwrap();
        inner.tasks.get(task).and_then(|r| r.exit_code)
    }

    /// Build the cluster spec if every expected task has an endpoint.
    /// After a surgical recovery the survivors' endpoints are still in
    /// place, so this completes as soon as the replacements register —
    /// a *partial* rebuild from the AM's point of view.
    pub fn try_build_spec(&self, version: u32) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.version != version || inner.spec.is_some() {
            return inner.spec.is_some();
        }
        let all_registered = inner
            .expected
            .iter()
            .all(|t| inner.tasks.get(t).map(|r| r.endpoint.is_some()).unwrap_or(false));
        if !all_registered {
            return false;
        }
        let mut spec = ClusterSpec::new(version as u64);
        for t in &inner.expected {
            let ep = inner.tasks[t].endpoint.clone().unwrap();
            spec.tasks.entry(t.job_type.clone()).or_default().push(ep);
        }
        inner.spec = Some(spec);
        // The initial rendezvous transitions to Running here; a recovery
        // stays in Recovering until the survivors ack the new version
        // (see `recovery_complete`).
        if inner.phase == JobPhase::Negotiating {
            inner.phase = JobPhase::Running;
        }
        drop(inner);
        if let Some(t) = self.trace() {
            // Every expected endpoint is in: registration is over and the
            // executors now sync the spec (GET_SPEC long-polls drain).
            t.end_stage(Stage::Registering);
            t.start_stage(Stage::SpecSync);
        }
        // Wakes the AM monitor loop AND every executor blocked in a
        // GET_SPEC long-poll (they ride the bus sequence).
        self.bus.notify(tag::SPEC);
        true
    }

    /// True when the patched spec is built *and* every live task has
    /// acked the current version — the recovery barrier.
    pub fn recovery_complete(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        let spec_ready = inner
            .spec
            .as_ref()
            .map(|s| s.version == inner.version as u64)
            .unwrap_or(false);
        spec_ready
            && inner.expected.iter().all(|t| {
                inner
                    .tasks
                    .get(t)
                    .map(|r| r.exit_code.is_some() || r.acked_version == inner.version)
                    .unwrap_or(false)
            })
    }

    /// Blocking spec fetch used by the RPC handler.  Succeeds once a spec
    /// at `version` *or newer* exists: a survivor asking for the version
    /// its Reconfigure named may race a further recovery, and the newest
    /// spec is always the right answer.  Event-driven: waiters ride the
    /// bus sequence (woken by `tag::SPEC`) instead of the old 50 ms
    /// re-check slices, and the deadline is clock-driven so manual-clock
    /// tests can expire it deterministically.
    fn wait_spec(&self, version: u32, timeout: Duration) -> Option<ClusterSpec> {
        let deadline = self.clock.deadline_after(timeout);
        loop {
            let seen = self.bus.seq();
            {
                let inner = self.inner.lock().unwrap();
                if let Some(spec) = &inner.spec {
                    if spec.version >= version as u64 {
                        return Some(spec.clone());
                    }
                }
                // The attempt is being torn down or the job ended: this
                // spec will never be built.  Fail the long-poll now so a
                // doomed executor unblocks and notices its kill switch
                // instead of waiting out the timeout.
                if matches!(
                    inner.phase,
                    JobPhase::Restarting | JobPhase::Succeeded | JobPhase::Failed
                ) {
                    return None;
                }
            }
            if self.clock.now_ms() >= deadline {
                return None;
            }
            self.bus.wait_seq(&*self.clock, seen, deadline);
        }
    }

    pub fn first_tracked_failure(&self, job: &JobSpec) -> Option<(TaskId, i64)> {
        let inner = self.inner.lock().unwrap();
        for r in inner.tasks.values() {
            let tracked = job.task_type(&r.task.job_type).map(|t| t.tracked).unwrap_or(true);
            if !tracked {
                continue;
            }
            if let Some(code) = r.exit_code {
                if code != 0 {
                    return Some((r.task.clone(), code));
                }
            }
        }
        None
    }

    pub fn all_tracked_succeeded(&self, job: &JobSpec) -> bool {
        let inner = self.inner.lock().unwrap();
        if inner.expected.is_empty() {
            return false;
        }
        inner.expected.iter().all(|t| {
            let tracked = job.task_type(&t.job_type).map(|tt| tt.tracked).unwrap_or(true);
            if !tracked {
                return true;
            }
            inner.tasks.get(t).and_then(|r| r.exit_code) == Some(0)
        })
    }

    pub fn all_untracked_done(&self, job: &JobSpec) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.expected.iter().all(|t| {
            let tracked = job.task_type(&t.job_type).map(|tt| tt.tracked).unwrap_or(true);
            tracked || inner.tasks.get(t).map(|r| r.exit_code.is_some()).unwrap_or(true)
        })
    }

    pub fn command_all_untracked(&self, job: &JobSpec, cmd: AmCommand) {
        let mut inner = self.inner.lock().unwrap();
        for r in inner.tasks.values_mut() {
            let tracked = job.task_type(&r.task.job_type).map(|t| t.tracked).unwrap_or(true);
            if !tracked && r.exit_code.is_none() {
                r.command = cmd;
            }
        }
    }

    /// A task that *registered* but has stopped heartbeating.
    pub fn stale_task(&self, budget: Duration) -> Option<TaskId> {
        let now = self.clock.now_ms();
        let budget = budget.as_millis() as u64;
        let inner = self.inner.lock().unwrap();
        for r in inner.tasks.values() {
            if r.exit_code.is_some() || r.endpoint.is_none() {
                continue;
            }
            if let Some(last) = r.last_heartbeat {
                if now.saturating_sub(last) > budget {
                    return Some(r.task.clone());
                }
            }
        }
        None
    }

    /// A task whose container launched but whose executor never
    /// registered within `budget`.  Without this check an executor that
    /// wedges between launch and registration hangs the attempt forever:
    /// the AM's launch timeout only covers *granting* containers, and the
    /// heartbeat staleness check only covers *registered* tasks.
    pub fn unregistered_task(&self, budget: Duration) -> Option<TaskId> {
        let now = self.clock.now_ms();
        let budget = budget.as_millis() as u64;
        let inner = self.inner.lock().unwrap();
        for r in inner.tasks.values() {
            if r.exit_code.is_some() || r.endpoint.is_some() || r.container.is_none() {
                continue;
            }
            if let Some(launched) = r.last_heartbeat {
                if now.saturating_sub(launched) > budget {
                    return Some(r.task.clone());
                }
            }
        }
        None
    }

    /// The earliest clock time (ms) at which a liveness verdict could
    /// change: the next heartbeat-staleness expiry over registered live
    /// tasks, or the next registration-deadline expiry over launched,
    /// still-unregistered tasks.  The monitor loop arms this on its
    /// timer wheel so it sleeps *exactly* until something can happen,
    /// instead of re-checking on a poll interval.
    pub fn next_liveness_deadline(
        &self,
        liveness_budget: Duration,
        registration_budget: Duration,
    ) -> Option<u64> {
        let live_ms = liveness_budget.as_millis() as u64;
        let reg_ms = registration_budget.as_millis() as u64;
        let inner = self.inner.lock().unwrap();
        let mut next: Option<u64> = None;
        for r in inner.tasks.values() {
            if r.exit_code.is_some() {
                continue;
            }
            let Some(last) = r.last_heartbeat else { continue };
            let deadline = if r.endpoint.is_some() {
                last.saturating_add(live_ms)
            } else if r.container.is_some() {
                last.saturating_add(reg_ms)
            } else {
                continue;
            };
            next = Some(next.map_or(deadline, |n: u64| n.min(deadline)));
        }
        next
    }

    /// First worker's UI URL (the TensorBoard stand-in, §2.2).
    pub fn ui_url(&self) -> Option<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .find_map(|r| r.ui_url.clone())
    }

    /// Portal snapshot: whole-job status as JSON.
    pub fn snapshot_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut tasks = Vec::new();
        for r in inner.tasks.values() {
            let mut t = Json::obj();
            t.set("task", r.task.to_string());
            t.set(
                "container",
                r.container.map(|c| Json::Str(c.to_string())).unwrap_or(Json::Null),
            );
            t.set(
                "endpoint",
                r.endpoint
                    .as_ref()
                    .map(|e| Json::Str(e.to_string()))
                    .unwrap_or(Json::Null),
            );
            t.set("generation", r.generation as u64);
            t.set("step", r.metrics.step);
            t.set("loss", r.metrics.loss as f64);
            t.set("tokens", r.metrics.tokens_done);
            t.set("step_ms", r.metrics.step_ms_avg);
            t.set("mem_mb", r.metrics.mem_used_mb);
            t.set("updates", r.metrics.updates_applied);
            t.set(
                "exit",
                r.exit_code.map(Json::from).unwrap_or(Json::Null),
            );
            t.set(
                "log_url",
                Json::Str(format!("/logs/{}", r.task)), // portal route
            );
            if let Some(u) = &r.ui_url {
                t.set("ui_url", u.as_str());
            }
            tasks.push(t);
        }
        let mut j = Json::obj();
        j.set("phase", format!("{:?}", inner.phase));
        j.set("attempt", inner.attempt as u64);
        j.set("version", inner.version as u64);
        j.set("recoveries", inner.recoveries as u64);
        j.set("released_grants", inner.released_grants);
        j.set("preempted", inner.preempted);
        j.set("grows", inner.grows as u64);
        j.set("shrinks", inner.shrinks as u64);
        j.set(
            "workers",
            inner
                .expected
                .iter()
                .filter(|t| t.job_type == crate::tonyconf::WORKER)
                .count() as u64,
        );
        j.set("uptime_ms", self.clock.now_ms().saturating_sub(inner.started_at_ms));
        j.set("tasks", Json::Arr(tasks));
        j.set(
            "spec_ready",
            inner.spec.is_some(),
        );
        j
    }

    /// Aggregate chief metrics (portal's loss curve).
    pub fn chief_metrics(&self) -> Option<TaskMetrics> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .get(&TaskId::new("worker", 0))
            .map(|r| r.metrics.clone())
    }
}

/// Fold one heartbeat's metrics into the record.  Scalars are replaced;
/// `loss_history` arrives as an *incremental delta* (entries newer than
/// the last delivered step — see the executor's heartbeat thread) and
/// is appended, bounded by `cap` (oldest dropped).
///
/// When the delta *overlaps* the accumulated curve — its first entry is
/// at/below the last recorded step — the sender re-trained those steps
/// (a relaunched task restoring from a checkpoint, or a survivor's sync
/// rollback) or re-sent after a lost reply.  The recorded entries from
/// the overlap point on are dropped and the new curve spliced in, which
/// keeps the fold idempotent under retransmission while never silently
/// discarding retrained losses.
fn fold_heartbeat_metrics(current: &mut TaskMetrics, incoming: TaskMetrics, cap: usize) {
    let mut hist = std::mem::take(&mut current.loss_history);
    if let Some(&(first, _)) = incoming.loss_history.first() {
        if hist.last().map_or(false, |&(hs, _)| first <= hs) {
            hist.retain(|&(s, _)| s < first);
        }
    }
    for &(s, l) in &incoming.loss_history {
        if hist.last().map_or(true, |&(hs, _)| s > hs) {
            hist.push((s, l));
        }
    }
    if hist.len() > cap {
        // Evict a chunk, not one entry per beat: the front-drain shifts
        // the whole vector, so doing it every heartbeat once the cap is
        // reached would put an O(cap) memmove on the hot path.  Dropping
        // a quarter of the cap at a time amortizes it to O(1) per entry.
        let excess = hist.len() - cap;
        hist.drain(..excess.max(cap / 4).min(hist.len()));
    }
    *current = incoming;
    current.loss_history = hist;
}

/// RPC dispatch for the executor-facing AM server.
pub struct AmRpcHandler {
    state: std::sync::Arc<AmState>,
}

impl AmRpcHandler {
    pub fn new(state: std::sync::Arc<AmState>) -> AmRpcHandler {
        AmRpcHandler { state }
    }
}

impl RpcHandler for AmRpcHandler {
    fn handle(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, String> {
        match method {
            AM_REGISTER => {
                let msg = RegisterMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                let version = inner.version;
                let rec = inner
                    .tasks
                    .entry(task.clone())
                    .or_insert_with(|| TaskRecord::new(task.clone(), version));
                // A registration is valid only from the incarnation we
                // launched (its launch version); anything older is a
                // zombie from a replaced incarnation.
                if msg.spec_version != rec.spec_version {
                    return Err(format!(
                        "stale registration from {task} (version {} != {})",
                        msg.spec_version, rec.spec_version
                    ));
                }
                rec.endpoint = Some(HostPort::new(msg.host.clone(), msg.port));
                rec.ui_url = msg.ui_url.clone();
                rec.last_heartbeat = Some(self.state.clock.now_ms());
                rec.acked_version = msg.spec_version;
                drop(inner);
                // Registration is an event the monitor loop (spec
                // assembly, recovery barrier) must see immediately.
                self.state.bus.notify(tag::REGISTERED);
                self.state.try_build_spec(msg.spec_version);
                Ok(Vec::new())
            }
            AM_GET_SPEC => {
                let msg = GetSpecMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                match self
                    .state
                    .wait_spec(msg.spec_version, Duration::from_millis(msg.timeout_ms))
                {
                    Some(spec) => {
                        let mut inner = self.state.inner.lock().unwrap();
                        inner.spec_fetches += 1;
                        let all_fetched = !inner.expected.is_empty()
                            && inner.spec_fetches >= inner.expected.len();
                        drop(inner);
                        if all_fetched {
                            if let Some(t) = self.state.trace() {
                                // Every executor holds the spec: training
                                // proper starts now.
                                t.end_stage(Stage::SpecSync);
                                t.start_stage(Stage::Running);
                            }
                        }
                        Ok(spec.to_tf_config("", 0).into_bytes())
                    }
                    None => Err("spec not ready".to_string()),
                }
            }
            AM_HEARTBEAT => {
                let msg = HeartbeatMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                let version = inner.version;
                let spec_ready = inner
                    .spec
                    .as_ref()
                    .map(|s| s.version == version as u64)
                    .unwrap_or(false);
                // Scalars captured before the fold consumes the message,
                // so the registry sample happens *outside* the state lock.
                let mut observed: Option<(u64, f64, f64, u64, bool)> = None;
                // Most heartbeats only refresh liveness and metrics; the
                // monitor loop is woken only when one carries *news* (a
                // spec-version ack the recovery barrier waits on), so a
                // busy job's heartbeat volume never turns back into a
                // poll-rate monitor loop.
                let mut acked_news = false;
                let cmd = match inner.tasks.get_mut(&task) {
                    Some(rec) if msg.spec_version >= rec.spec_version => {
                        rec.last_heartbeat = Some(self.state.clock.now_ms());
                        observed = Some((
                            msg.metrics.step,
                            msg.metrics.loss as f64,
                            msg.metrics.step_ms_avg,
                            msg.metrics.mem_used_mb,
                            msg.metrics.finished,
                        ));
                        fold_heartbeat_metrics(
                            &mut rec.metrics,
                            msg.metrics,
                            self.state.loss_history_cap,
                        );
                        let acked = msg.spec_version.min(version);
                        acked_news = acked != rec.acked_version;
                        rec.acked_version = acked;
                        if rec.command != AmCommand::None {
                            rec.command
                        } else if msg.spec_version < version && spec_ready {
                            // Survivor of a surgical recovery: hand it
                            // the patched spec version to re-fetch.
                            AmCommand::Reconfigure
                        } else {
                            AmCommand::None
                        }
                    }
                    // Zombie from a replaced incarnation or a torn-down
                    // attempt: tell it to die.
                    _ => AmCommand::Abort,
                };
                drop(inner);
                if acked_news {
                    self.state.bus.notify(tag::HEARTBEAT);
                }
                if self.state.registry.enabled() {
                    if let Some((step, loss, step_ms, mem, force)) = observed {
                        self.state.registry.observe_task(
                            &task.to_string(),
                            step,
                            loss,
                            step_ms,
                            mem,
                            force,
                        );
                    }
                }
                Ok(HeartbeatReply { command: cmd, spec_version: version }.to_bytes())
            }
            AM_FINISHED => {
                let msg = FinishedMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                let mut exited = false;
                if let Some(rec) = inner.tasks.get_mut(&task) {
                    // Only the current incarnation may report an exit.
                    if msg.spec_version >= rec.spec_version {
                        rec.exit_code = Some(msg.exit_code);
                        rec.metrics.finished = true;
                        exited = true;
                    }
                }
                drop(inner);
                if exited {
                    if let Some(t) = self.state.trace() {
                        t.event(
                            Stage::Running,
                            &format!("exit {task}"),
                            t.stage_span(Stage::Running),
                            &[("code", msg.exit_code.to_string())],
                        );
                    }
                    // Success/failure detection is exit-event-driven.
                    self.state.bus.notify(tag::TASK_EXIT);
                }
                Ok(Vec::new())
            }
            AM_STATUS => Ok(self.state.snapshot_json().render().into_bytes()),
            m => Err(format!("unknown AM method {m}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::{JobConfBuilder, JobSpec};
    use crate::util::ManualClock;

    fn job() -> JobSpec {
        let conf = JobConfBuilder::new("t")
            .instances("worker", 2)
            .instances("ps", 1)
            .build();
        JobSpec::from_conf(&conf).unwrap()
    }

    /// AmState on a manual clock: the test owns liveness time.
    fn manual_state(job: &JobSpec) -> (std::sync::Arc<ManualClock>, AmState) {
        let clock = ManualClock::shared();
        (clock.clone(), AmState::with_clock(job, clock))
    }

    #[test]
    fn spec_builds_when_all_registered() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(1);
        assert!(!state.try_build_spec(1));
        {
            let mut inner = state.inner.lock().unwrap();
            for (i, t) in inner.expected.clone().iter().enumerate() {
                inner.tasks.get_mut(t).unwrap().endpoint =
                    Some(HostPort::localhost(6000 + i as u16));
            }
        }
        assert!(state.try_build_spec(1));
        let spec = state.wait_spec(1, Duration::from_millis(10)).unwrap();
        assert_eq!(spec.endpoints("worker").len(), 2);
        assert_eq!(spec.endpoints("ps").len(), 1);
        assert_eq!(spec.version, 1);
    }

    #[test]
    fn tracked_success_and_failure_detection() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(1);
        assert!(!state.all_tracked_succeeded(&job));
        {
            let mut inner = state.inner.lock().unwrap();
            inner.tasks.get_mut(&TaskId::new("worker", 0)).unwrap().exit_code = Some(0);
            inner.tasks.get_mut(&TaskId::new("worker", 1)).unwrap().exit_code = Some(0);
        }
        // PS still running but untracked -> job counts as done.
        assert!(state.all_tracked_succeeded(&job));
        assert!(state.first_tracked_failure(&job).is_none());
        {
            let mut inner = state.inner.lock().unwrap();
            inner.tasks.get_mut(&TaskId::new("worker", 1)).unwrap().exit_code = Some(1);
        }
        let (t, code) = state.first_tracked_failure(&job).unwrap();
        assert_eq!(t, TaskId::new("worker", 1));
        assert_eq!(code, 1);
    }

    #[test]
    fn heartbeat_and_stale_detection() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        // Register worker:0 so it is subject to heartbeat liveness.
        let reg = RegisterMsg {
            task_type: "worker".into(),
            index: 0,
            host: "127.0.0.1".into(),
            port: 1234,
            ui_url: None,
            spec_version: 1,
        };
        handler.handle(AM_REGISTER, &reg.to_bytes()).unwrap();
        let hb = HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics { step: 3, ..Default::default() },
        };
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::None);
        // Zombie heartbeat from an old incarnation gets Abort.
        let old = HeartbeatMsg { spec_version: 0, ..hb.clone() };
        let resp = handler.handle(AM_HEARTBEAT, &old.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::Abort);
        // The heartbeated task is fresh; others have no heartbeat at all
        // (never launched) and are not stale either.
        assert!(state.stale_task(Duration::from_secs(60)).is_none());
    }

    /// Liveness expiry on a manual clock — no real sleeping: advancing
    /// virtual time past the budget is what makes the task stale, and
    /// `next_liveness_deadline` names the exact expiry the monitor loop
    /// arms on its timer wheel.
    #[test]
    fn stale_detection_is_clock_driven() {
        let job = job();
        let (clock, state) = manual_state(&job);
        state.begin_attempt(1);
        {
            let mut inner = state.inner.lock().unwrap();
            let rec = inner.tasks.get_mut(&TaskId::new("worker", 0)).unwrap();
            rec.endpoint = Some(HostPort::localhost(1234));
            rec.last_heartbeat = Some(clock.now_ms());
        }
        let budget = Duration::from_millis(100);
        assert!(state.stale_task(budget).is_none());
        assert_eq!(
            state.next_liveness_deadline(budget, Duration::from_millis(500)),
            Some(100),
            "wheel deadline = last heartbeat + liveness budget"
        );
        clock.advance_ms(100);
        assert!(state.stale_task(budget).is_none(), "exactly at budget is alive");
        clock.advance_ms(1);
        assert_eq!(state.stale_task(budget), Some(TaskId::new("worker", 0)));
    }

    #[test]
    fn launched_but_unregistered_task_is_flagged() {
        let job = job();
        let (clock, state) = manual_state(&job);
        state.begin_attempt(1);
        // Nothing launched -> nothing can be flagged, ever.
        assert!(state.unregistered_task(Duration::from_millis(0)).is_none());
        let cid = ContainerId {
            app: crate::util::ids::ApplicationId { cluster_ts: 1, seq: 1 },
            seq: 1,
        };
        state.record_launch(TaskId::new("worker", 1), cid);
        // Fresh launch is within its registration grace.
        assert!(state.unregistered_task(Duration::from_secs(60)).is_none());
        clock.advance_ms(30);
        // Past the deadline with no registration -> flagged (this is the
        // regression for the pre-registration wedge hang).  Virtual time
        // alone trips it: zero real sleeping.
        assert_eq!(
            state.unregistered_task(Duration::from_millis(1)),
            Some(TaskId::new("worker", 1))
        );
        // Once registered, the registration deadline no longer applies.
        {
            let mut inner = state.inner.lock().unwrap();
            inner.tasks.get_mut(&TaskId::new("worker", 1)).unwrap().endpoint =
                Some(HostPort::localhost(7001));
        }
        assert!(state.unregistered_task(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn surgical_recovery_reconfigures_survivors() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        // Everyone registers at version 1; spec builds.
        let mut port = 6000u16;
        for t in [("worker", 0), ("worker", 1), ("ps", 0)] {
            let reg = RegisterMsg {
                task_type: t.0.into(),
                index: t.1,
                host: "127.0.0.1".into(),
                port,
                ui_url: None,
                spec_version: 1,
            };
            handler.handle(AM_REGISTER, &reg.to_bytes()).unwrap();
            port += 1;
        }
        assert!(state.try_build_spec(1));
        assert_eq!(state.phase(), JobPhase::Running);

        // worker:1 dies; surgical recovery begins at version 2.
        let v2 = state.begin_recovery(&[TaskId::new("worker", 1)]);
        assert_eq!(v2, 2);
        assert_eq!(state.phase(), JobPhase::Recovering);
        assert!(!state.recovery_complete());

        // Survivor heartbeats at version 1: alive, but no Reconfigure
        // until the patched spec exists.
        let hb = HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics::default(),
        };
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::None);

        // Zombie of the replaced worker:1 (old incarnation) is aborted.
        let zombie = HeartbeatMsg { index: 1, ..hb.clone() };
        let resp = handler.handle(AM_HEARTBEAT, &zombie.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::Abort);

        // Replacement registers at version 2 -> spec rebuilds (partial:
        // survivors kept their endpoints).
        let reg = RegisterMsg {
            task_type: "worker".into(),
            index: 1,
            host: "127.0.0.1".into(),
            port: 6100,
            ui_url: None,
            spec_version: 2,
        };
        handler.handle(AM_REGISTER, &reg.to_bytes()).unwrap();
        assert!(state.try_build_spec(2));
        let spec = state.wait_spec(2, Duration::from_millis(10)).unwrap();
        assert_eq!(spec.version, 2);
        assert_eq!(spec.endpoints("worker")[1], HostPort::localhost(6100));
        // Survivor endpoints untouched.
        assert_eq!(spec.endpoints("worker")[0], HostPort::localhost(6000));

        // Now the survivor's old-version heartbeat earns a Reconfigure.
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        let reply = HeartbeatReply::from_bytes(&resp);
        assert_eq!(reply.command, AmCommand::Reconfigure);
        assert_eq!(reply.spec_version, 2);
        assert!(!state.recovery_complete(), "survivors have not acked v2 yet");

        // Survivors ack by heartbeating at the new version.
        for idx in [0u32] {
            let hb2 = HeartbeatMsg { index: idx, spec_version: 2, ..hb.clone() };
            let resp = handler.handle(AM_HEARTBEAT, &hb2.to_bytes()).unwrap();
            assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::None);
        }
        let ps_hb = HeartbeatMsg { task_type: "ps".into(), index: 0, spec_version: 2, ..hb };
        handler.handle(AM_HEARTBEAT, &ps_hb.to_bytes()).unwrap();
        assert!(state.recovery_complete());
    }

    #[test]
    fn untracked_stop_commands() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        state.command_all_untracked(&job, AmCommand::Stop);
        let handler = AmRpcHandler::new(state.clone());
        let hb = HeartbeatMsg {
            task_type: "ps".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics::default(),
        };
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::Stop);
        // Worker heartbeats still get None.
        let hbw = HeartbeatMsg { task_type: "worker".into(), ..hb };
        let resp = handler.handle(AM_HEARTBEAT, &hbw.to_bytes()).unwrap();
        assert_eq!(HeartbeatReply::from_bytes(&resp).command, AmCommand::None);
    }

    #[test]
    fn heartbeat_folds_loss_history_deltas() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        let hb = |hist: Vec<(u64, f32)>| HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics {
                step: hist.last().map(|&(s, _)| s).unwrap_or(0),
                loss_history: hist,
                ..Default::default()
            },
        };
        handler.handle(AM_HEARTBEAT, &hb(vec![(1, 5.0), (2, 4.0)]).to_bytes()).unwrap();
        // Next heartbeat carries only the delta; the AM appends it.
        handler.handle(AM_HEARTBEAT, &hb(vec![(3, 3.0)]).to_bytes()).unwrap();
        // A re-sent delta (transport retry) must not double-record.
        handler.handle(AM_HEARTBEAT, &hb(vec![(3, 3.0)]).to_bytes()).unwrap();
        let m = state.chief_metrics().unwrap();
        assert_eq!(m.loss_history, vec![(1, 5.0), (2, 4.0), (3, 3.0)]);
        assert_eq!(m.step, 3, "scalars track the latest heartbeat");
        // The scalar snapshot carries no history.
        let tasks = state.task_metrics();
        let (_, w0) = tasks.iter().find(|(t, _)| t == "worker:0").unwrap();
        assert!(w0.loss_history.is_empty());
        assert_eq!(w0.step, 3);
    }

    #[test]
    fn recovery_splices_replacement_loss_history() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        let hb = |version: u32, hist: Vec<(u64, f32)>| HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: version,
            metrics: TaskMetrics { loss_history: hist, ..Default::default() },
        };
        // The original incarnation trains to step 3 ...
        handler
            .handle(AM_HEARTBEAT, &hb(1, vec![(1, 5.0), (2, 4.0), (3, 3.5)]).to_bytes())
            .unwrap();
        // ... then dies; the replacement restores from the step-1
        // checkpoint and retrains steps 2..3.
        state.begin_recovery(&[TaskId::new("worker", 0)]);
        // An empty warm-up delta (pre-training heartbeat) is a no-op.
        handler.handle(AM_HEARTBEAT, &hb(2, vec![]).to_bytes()).unwrap();
        handler.handle(AM_HEARTBEAT, &hb(2, vec![(2, 4.4)]).to_bytes()).unwrap();
        handler.handle(AM_HEARTBEAT, &hb(2, vec![(3, 3.9)]).to_bytes()).unwrap();
        let m = state.chief_metrics().unwrap();
        // Pre-restore curve kept, dead incarnation's tail replaced by
        // the replacement's actual losses (not silently dropped).
        assert_eq!(m.loss_history, vec![(1, 5.0), (2, 4.4), (3, 3.9)]);
    }

    #[test]
    fn heartbeats_feed_the_metrics_registry() {
        let conf = JobConfBuilder::new("reg")
            .instances("worker", 1)
            .set("tony.metrics.sample-interval-ms", "1")
            .set("tony.metrics.retention-points", "8")
            .build();
        let job = JobSpec::from_conf(&conf).unwrap();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        for step in 1..=3u64 {
            let hb = HeartbeatMsg {
                task_type: "worker".into(),
                index: 0,
                spec_version: 1,
                metrics: TaskMetrics {
                    step,
                    loss: 1.0,
                    finished: step == 3, // final flush forces a sample
                    ..Default::default()
                },
            };
            handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
            // Real time: the registry's sample rate limit is wall-clock.
            crate::util::clock::real_sleep(Duration::from_millis(2));
        }
        let pts = state.metrics_registry().task_points("worker:0", "step");
        assert!(!pts.is_empty(), "heartbeats must land in the registry");
        assert_eq!(pts.last().unwrap().1, 3.0, "final flush sampled");
        // Zombie heartbeats never pollute the series.
        let zombie = HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: 0,
            metrics: TaskMetrics { step: 99, ..Default::default() },
        };
        handler.handle(AM_HEARTBEAT, &zombie.to_bytes()).unwrap();
        let pts = state.metrics_registry().task_points("worker:0", "step");
        assert_eq!(pts.last().unwrap().1, 3.0);
    }

    #[test]
    fn snapshot_json_shape() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(2);
        let j = state.snapshot_json();
        assert_eq!(j.get("attempt").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("recoveries").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("released_grants").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("tasks").unwrap().as_arr().unwrap().len(), 3);
    }
}
