//! Shared AM state: task registry, cluster-spec assembly, heartbeat
//! liveness, and the RPC handler the TaskExecutors talk to.  The portal
//! reads snapshots of this concurrently.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::framework::protocol::{ClusterSpec, TaskMetrics};
use crate::json::Json;
use crate::net::rpc::RpcHandler;
use crate::net::wire::Wire;
use crate::tonyconf::JobSpec;
use crate::util::ids::{ContainerId, TaskId};
use crate::util::HostPort;

use super::protocol::*;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    Negotiating,
    Running,
    Restarting,
    Succeeded,
    Failed,
}

#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    pub container: Option<ContainerId>,
    pub endpoint: Option<HostPort>,
    pub ui_url: Option<String>,
    pub last_heartbeat: Option<Instant>,
    pub metrics: TaskMetrics,
    pub exit_code: Option<i64>,
    pub command: AmCommand,
    pub spec_version: u32,
}

impl TaskRecord {
    fn new(task: TaskId, spec_version: u32) -> TaskRecord {
        TaskRecord {
            task,
            container: None,
            endpoint: None,
            ui_url: None,
            last_heartbeat: None,
            metrics: TaskMetrics::default(),
            exit_code: None,
            command: AmCommand::None,
            spec_version,
        }
    }
}

#[derive(Debug)]
struct Inner {
    attempt: u32,
    phase: JobPhase,
    tasks: BTreeMap<TaskId, TaskRecord>,
    expected: Vec<TaskId>,
    spec: Option<ClusterSpec>,
    started_at: Instant,
}

/// The outcome of one attempt, as decided by the AM monitor loop.
#[derive(Debug, Clone, PartialEq)]
pub enum AttemptOutcome {
    Succeeded,
    TaskFailed(String),
    AmKilled,
}

pub struct AmState {
    inner: Mutex<Inner>,
    cond: Condvar,
    expected_from: Box<dyn Fn(u32) -> Vec<TaskId> + Send + Sync>,
}

impl AmState {
    pub fn new(job: &JobSpec) -> AmState {
        let types: Vec<(String, u32)> = job
            .task_types
            .iter()
            .map(|t| (t.name.clone(), t.instances))
            .collect();
        let expected_from = Box::new(move |_attempt: u32| {
            let mut out = Vec::new();
            for (ty, n) in &types {
                for i in 0..*n {
                    out.push(TaskId::new(ty.clone(), i));
                }
            }
            out
        });
        AmState {
            inner: Mutex::new(Inner {
                attempt: 0,
                phase: JobPhase::Negotiating,
                tasks: BTreeMap::new(),
                expected: Vec::new(),
                spec: None,
                started_at: Instant::now(),
            }),
            cond: Condvar::new(),
            expected_from,
        }
    }

    pub fn begin_attempt(&self, attempt: u32) {
        let mut inner = self.inner.lock().unwrap();
        inner.attempt = attempt;
        inner.phase = JobPhase::Negotiating;
        inner.spec = None;
        inner.expected = (self.expected_from)(attempt);
        inner.tasks = inner
            .expected
            .iter()
            .map(|t| (t.clone(), TaskRecord::new(t.clone(), attempt)))
            .collect();
        self.cond.notify_all();
    }

    pub fn set_phase(&self, phase: JobPhase) {
        self.inner.lock().unwrap().phase = phase;
        self.cond.notify_all();
    }

    pub fn phase(&self) -> JobPhase {
        self.inner.lock().unwrap().phase
    }

    pub fn attempt(&self) -> u32 {
        self.inner.lock().unwrap().attempt
    }

    pub fn record_launch(&self, task: TaskId, container: ContainerId) {
        let mut inner = self.inner.lock().unwrap();
        let attempt = inner.attempt;
        let rec = inner
            .tasks
            .entry(task.clone())
            .or_insert_with(|| TaskRecord::new(task, attempt));
        rec.container = Some(container);
        rec.last_heartbeat = Some(Instant::now()); // launch counts as life
    }

    pub fn task_for_container(&self, container: ContainerId) -> Option<TaskId> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .find(|r| r.container == Some(container))
            .map(|r| r.task.clone())
    }

    pub fn forget_container(&self, container: ContainerId) {
        let mut inner = self.inner.lock().unwrap();
        for r in inner.tasks.values_mut() {
            if r.container == Some(container) {
                r.container = None;
            }
        }
    }

    pub fn live_containers(&self) -> Vec<ContainerId> {
        let inner = self.inner.lock().unwrap();
        inner.tasks.values().filter_map(|r| r.container).collect()
    }

    /// The container currently hosting `task`, if it is still live
    /// (chaos-injection targeting).
    pub fn live_containers_for(&self, task: &TaskId) -> Option<ContainerId> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .get(task)
            .filter(|r| r.exit_code.is_none())
            .and_then(|r| r.container)
    }

    pub fn task_exit(&self, task: &TaskId) -> Option<i64> {
        let inner = self.inner.lock().unwrap();
        inner.tasks.get(task).and_then(|r| r.exit_code)
    }

    /// Build the cluster spec if every expected task has registered.
    pub fn try_build_spec(&self, attempt: u32) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.attempt != attempt || inner.spec.is_some() {
            return inner.spec.is_some();
        }
        let all_registered = inner
            .expected
            .iter()
            .all(|t| inner.tasks.get(t).map(|r| r.endpoint.is_some()).unwrap_or(false));
        if !all_registered {
            return false;
        }
        let mut spec = ClusterSpec::new(attempt as u64);
        for t in &inner.expected {
            let ep = inner.tasks[t].endpoint.clone().unwrap();
            spec.tasks.entry(t.job_type.clone()).or_default().push(ep);
        }
        inner.spec = Some(spec);
        inner.phase = JobPhase::Running;
        self.cond.notify_all();
        true
    }

    /// Blocking spec fetch used by the RPC handler.
    fn wait_spec(&self, version: u32, timeout: Duration) -> Option<ClusterSpec> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.attempt == version {
                if let Some(spec) = &inner.spec {
                    return Some(spec.clone());
                }
            }
            if inner.attempt > version {
                return None; // dead attempt
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self
                .cond
                .wait_timeout(inner, (deadline - now).min(Duration::from_millis(50)))
                .unwrap();
            inner = g;
        }
    }

    pub fn first_tracked_failure(&self, job: &JobSpec) -> Option<(TaskId, i64)> {
        let inner = self.inner.lock().unwrap();
        for r in inner.tasks.values() {
            if r.spec_version != inner.attempt {
                continue;
            }
            let tracked = job.task_type(&r.task.job_type).map(|t| t.tracked).unwrap_or(true);
            if !tracked {
                continue;
            }
            if let Some(code) = r.exit_code {
                if code != 0 {
                    return Some((r.task.clone(), code));
                }
            }
        }
        None
    }

    pub fn all_tracked_succeeded(&self, job: &JobSpec) -> bool {
        let inner = self.inner.lock().unwrap();
        if inner.expected.is_empty() {
            return false;
        }
        inner.expected.iter().all(|t| {
            let tracked = job.task_type(&t.job_type).map(|tt| tt.tracked).unwrap_or(true);
            if !tracked {
                return true;
            }
            inner.tasks.get(t).and_then(|r| r.exit_code) == Some(0)
        })
    }

    pub fn all_untracked_done(&self, job: &JobSpec) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.expected.iter().all(|t| {
            let tracked = job.task_type(&t.job_type).map(|tt| tt.tracked).unwrap_or(true);
            tracked || inner.tasks.get(t).map(|r| r.exit_code.is_some()).unwrap_or(true)
        })
    }

    pub fn command_all_untracked(&self, job: &JobSpec, cmd: AmCommand) {
        let mut inner = self.inner.lock().unwrap();
        for r in inner.tasks.values_mut() {
            let tracked = job.task_type(&r.task.job_type).map(|t| t.tracked).unwrap_or(true);
            if !tracked && r.exit_code.is_none() {
                r.command = cmd;
            }
        }
    }

    /// A task that *registered* but has stopped heartbeating.  Tasks that
    /// are still starting up (engine compilation can take tens of seconds)
    /// are covered by the AM's launch timeout instead.
    pub fn stale_task(&self, budget: Duration) -> Option<TaskId> {
        let inner = self.inner.lock().unwrap();
        for r in inner.tasks.values() {
            if r.exit_code.is_some() || r.spec_version != inner.attempt || r.endpoint.is_none() {
                continue;
            }
            if let Some(last) = r.last_heartbeat {
                if last.elapsed() > budget {
                    return Some(r.task.clone());
                }
            }
        }
        None
    }

    /// First worker's UI URL (the TensorBoard stand-in, §2.2).
    pub fn ui_url(&self) -> Option<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .values()
            .find_map(|r| r.ui_url.clone())
    }

    /// Portal snapshot: whole-job status as JSON.
    pub fn snapshot_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut tasks = Vec::new();
        for r in inner.tasks.values() {
            let mut t = Json::obj();
            t.set("task", r.task.to_string());
            t.set(
                "container",
                r.container.map(|c| Json::Str(c.to_string())).unwrap_or(Json::Null),
            );
            t.set(
                "endpoint",
                r.endpoint
                    .as_ref()
                    .map(|e| Json::Str(e.to_string()))
                    .unwrap_or(Json::Null),
            );
            t.set("step", r.metrics.step);
            t.set("loss", r.metrics.loss as f64);
            t.set("tokens", r.metrics.tokens_done);
            t.set("step_ms", r.metrics.step_ms_avg);
            t.set("mem_mb", r.metrics.mem_used_mb);
            t.set("updates", r.metrics.updates_applied);
            t.set(
                "exit",
                r.exit_code.map(Json::from).unwrap_or(Json::Null),
            );
            t.set(
                "log_url",
                Json::Str(format!("/logs/{}", r.task)), // portal route
            );
            if let Some(u) = &r.ui_url {
                t.set("ui_url", u.as_str());
            }
            tasks.push(t);
        }
        let mut j = Json::obj();
        j.set("phase", format!("{:?}", inner.phase));
        j.set("attempt", inner.attempt as u64);
        j.set("uptime_ms", inner.started_at.elapsed().as_millis() as u64);
        j.set("tasks", Json::Arr(tasks));
        j.set(
            "spec_ready",
            inner.spec.is_some(),
        );
        j
    }

    /// Aggregate chief metrics (portal's loss curve).
    pub fn chief_metrics(&self) -> Option<TaskMetrics> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .get(&TaskId::new("worker", 0))
            .map(|r| r.metrics.clone())
    }
}

/// RPC dispatch for the executor-facing AM server.
pub struct AmRpcHandler {
    state: std::sync::Arc<AmState>,
}

impl AmRpcHandler {
    pub fn new(state: std::sync::Arc<AmState>) -> AmRpcHandler {
        AmRpcHandler { state }
    }
}

impl RpcHandler for AmRpcHandler {
    fn handle(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>, String> {
        match method {
            AM_REGISTER => {
                let msg = RegisterMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                if msg.spec_version != inner.attempt {
                    return Err(format!(
                        "stale registration from {task} (attempt {} != {})",
                        msg.spec_version, inner.attempt
                    ));
                }
                let attempt = inner.attempt;
                let rec = inner
                    .tasks
                    .entry(task.clone())
                    .or_insert_with(|| TaskRecord::new(task, attempt));
                rec.endpoint = Some(HostPort::new(msg.host.clone(), msg.port));
                rec.ui_url = msg.ui_url.clone();
                rec.last_heartbeat = Some(Instant::now());
                drop(inner);
                self.state.cond.notify_all();
                self.state.try_build_spec(msg.spec_version);
                Ok(Vec::new())
            }
            AM_GET_SPEC => {
                let msg = GetSpecMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                match self
                    .state
                    .wait_spec(msg.spec_version, Duration::from_millis(msg.timeout_ms))
                {
                    Some(spec) => Ok(spec.to_tf_config("", 0).into_bytes()),
                    None => Err("spec not ready".to_string()),
                }
            }
            AM_HEARTBEAT => {
                let msg = HeartbeatMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                if msg.spec_version != inner.attempt {
                    // Zombie from a torn-down attempt: tell it to die.
                    return Ok(vec![AmCommand::Abort as u8]);
                }
                let cmd = match inner.tasks.get_mut(&task) {
                    Some(rec) => {
                        rec.last_heartbeat = Some(Instant::now());
                        rec.metrics = msg.metrics;
                        rec.command
                    }
                    None => AmCommand::Abort,
                };
                Ok(vec![cmd as u8])
            }
            AM_FINISHED => {
                let msg = FinishedMsg::from_bytes(payload).map_err(|e| e.to_string())?;
                let task = TaskId::new(msg.task_type.clone(), msg.index);
                let mut inner = self.state.inner.lock().unwrap();
                if msg.spec_version == inner.attempt {
                    if let Some(rec) = inner.tasks.get_mut(&task) {
                        rec.exit_code = Some(msg.exit_code);
                        rec.metrics.finished = true;
                    }
                }
                Ok(Vec::new())
            }
            AM_STATUS => Ok(self.state.snapshot_json().render().into_bytes()),
            m => Err(format!("unknown AM method {m}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::{JobConfBuilder, JobSpec};

    fn job() -> JobSpec {
        let conf = JobConfBuilder::new("t")
            .instances("worker", 2)
            .instances("ps", 1)
            .build();
        JobSpec::from_conf(&conf).unwrap()
    }

    #[test]
    fn spec_builds_when_all_registered() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(1);
        assert!(!state.try_build_spec(1));
        let handler = AmRpcHandler::new(std::sync::Arc::new(AmState::new(&job)));
        let _ = handler; // separate handler instance unused below
        {
            let mut inner = state.inner.lock().unwrap();
            for (i, t) in inner.expected.clone().iter().enumerate() {
                inner.tasks.get_mut(t).unwrap().endpoint =
                    Some(HostPort::localhost(6000 + i as u16));
            }
        }
        assert!(state.try_build_spec(1));
        let spec = state.wait_spec(1, Duration::from_millis(10)).unwrap();
        assert_eq!(spec.endpoints("worker").len(), 2);
        assert_eq!(spec.endpoints("ps").len(), 1);
        assert_eq!(spec.version, 1);
    }

    #[test]
    fn tracked_success_and_failure_detection() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(1);
        assert!(!state.all_tracked_succeeded(&job));
        {
            let mut inner = state.inner.lock().unwrap();
            inner.tasks.get_mut(&TaskId::new("worker", 0)).unwrap().exit_code = Some(0);
            inner.tasks.get_mut(&TaskId::new("worker", 1)).unwrap().exit_code = Some(0);
        }
        // PS still running but untracked -> job counts as done.
        assert!(state.all_tracked_succeeded(&job));
        assert!(state.first_tracked_failure(&job).is_none());
        {
            let mut inner = state.inner.lock().unwrap();
            inner.tasks.get_mut(&TaskId::new("worker", 1)).unwrap().exit_code = Some(1);
        }
        let (t, code) = state.first_tracked_failure(&job).unwrap();
        assert_eq!(t, TaskId::new("worker", 1));
        assert_eq!(code, 1);
    }

    #[test]
    fn heartbeat_and_stale_detection() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        // Register worker:0 so it is subject to heartbeat liveness.
        let reg = RegisterMsg {
            task_type: "worker".into(),
            index: 0,
            host: "127.0.0.1".into(),
            port: 1234,
            ui_url: None,
            spec_version: 1,
        };
        handler.handle(AM_REGISTER, &reg.to_bytes()).unwrap();
        let hb = HeartbeatMsg {
            task_type: "worker".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics { step: 3, ..Default::default() },
        };
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        assert_eq!(AmCommand::from_u8(resp[0]), AmCommand::None);
        // Zombie heartbeat from an old attempt gets Abort.
        let old = HeartbeatMsg { spec_version: 0, ..hb.clone() };
        let resp = handler.handle(AM_HEARTBEAT, &old.to_bytes()).unwrap();
        assert_eq!(AmCommand::from_u8(resp[0]), AmCommand::Abort);
        // The heartbeated task is fresh; others have no heartbeat at all
        // (never launched) and are not stale either.
        assert!(state.stale_task(Duration::from_secs(60)).is_none());
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(
            state.stale_task(Duration::from_millis(1)),
            Some(TaskId::new("worker", 0))
        );
    }

    #[test]
    fn untracked_stop_commands() {
        let job = job();
        let state = std::sync::Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        state.command_all_untracked(&job, AmCommand::Stop);
        let handler = AmRpcHandler::new(state.clone());
        let hb = HeartbeatMsg {
            task_type: "ps".into(),
            index: 0,
            spec_version: 1,
            metrics: TaskMetrics::default(),
        };
        let resp = handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        assert_eq!(AmCommand::from_u8(resp[0]), AmCommand::Stop);
        // Worker heartbeats still get None.
        let hbw = HeartbeatMsg { task_type: "worker".into(), ..hb };
        let resp = handler.handle(AM_HEARTBEAT, &hbw.to_bytes()).unwrap();
        assert_eq!(AmCommand::from_u8(resp[0]), AmCommand::None);
    }

    #[test]
    fn snapshot_json_shape() {
        let job = job();
        let state = AmState::new(&job);
        state.begin_attempt(2);
        let j = state.snapshot_json();
        assert_eq!(j.get("attempt").unwrap().as_u64(), Some(2));
        assert_eq!(j.get("tasks").unwrap().as_arr().unwrap().len(), 3);
    }
}
