//! The TonY ApplicationMaster (paper §2.2) — the heart of the system.
//!
//! Responsibilities, exactly as the paper lays them out:
//!
//! 1. negotiate with the RM for all task containers, with heterogeneous
//!    requests per task type (GPU workers, CPU-only PS);
//! 2. launch a TaskExecutor in every granted container;
//! 3. collect each TaskExecutor's (host, port) registration; when all
//!    have registered, construct the **global cluster spec** and hand it
//!    back to every executor;
//! 4. monitor heartbeats and task exit statuses;
//! 5. on any tracked-task failure: tear down the remaining tasks, request
//!    fresh containers, build a new cluster spec (bumped version), and
//!    relaunch — tasks restore from the last checkpoint;
//! 6. report the first worker's UI URL + task logs to the client via the
//!    RM tracking URL.

pub mod protocol;
pub mod state;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::executor::{run_task_executor, ExecutorParams};
use crate::net::rpc::RpcServer;
use crate::tonyconf::JobSpec;
use crate::util::ids::{ApplicationId, TaskId};
use crate::util::HostPort;
use crate::yarn::{Container, ContainerCtx, ExitStatus, ResourceManager};
use crate::{tdebug, tinfo, twarn};

pub use protocol::{AmCommand, FinishedMsg, HeartbeatMsg, RegisterMsg};
pub use state::{AmState, AttemptOutcome, JobPhase, TaskRecord};

/// Result of one whole AM run (exposed for tests/portal).
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub succeeded: bool,
    pub attempts_used: u32,
    pub diagnostics: String,
}

/// Everything the AM needs besides the RM connection.
pub struct AmContext {
    pub rm: Arc<ResourceManager>,
    pub app: ApplicationId,
    pub job: Arc<JobSpec>,
    pub preset_dir: PathBuf,
    /// Shared state — the portal reads this concurrently.
    pub state: Arc<AmState>,
}

/// Run the ApplicationMaster to completion.  Returns the container exit
/// code (0 = job succeeded within the attempt budget).
pub fn run_application_master(am: AmContext, ctx: &ContainerCtx) -> i32 {
    match am_body(&am, ctx) {
        Ok(result) => {
            am.rm
                .finish_application(am.app, result.succeeded, &result.diagnostics);
            if result.succeeded {
                0
            } else {
                1
            }
        }
        Err(e) => {
            twarn!("am", "{} AM error: {e:#}", am.app);
            am.rm.finish_application(am.app, false, &format!("AM error: {e:#}"));
            1
        }
    }
}

fn am_body(am: &AmContext, ctx: &ContainerCtx) -> Result<JobResult> {
    let job = &am.job;
    am.rm.register_am(am.app, None).context("registering AM")?;

    // The AM's RPC endpoint that all TaskExecutors talk to.
    let server = RpcServer::serve(Arc::new(state::AmRpcHandler::new(am.state.clone())))
        .map_err(|e| anyhow::anyhow!("am rpc server: {e}"))?;
    let am_addr = server.addr();
    tinfo!("am", "{} AM up at {am_addr}; job '{}' ({} tasks)", am.app, job.name, job.total_tasks());

    let mut attempts_used = 0;
    let mut last_error = String::new();
    while attempts_used < job.max_attempts {
        attempts_used += 1;
        am.state.begin_attempt(attempts_used);
        tinfo!("am", "{} attempt {attempts_used}/{}", am.app, job.max_attempts);
        match run_attempt(am, ctx, &am_addr, attempts_used) {
            Ok(AttemptOutcome::Succeeded) => {
                am.state.set_phase(JobPhase::Succeeded);
                return Ok(JobResult {
                    succeeded: true,
                    attempts_used,
                    diagnostics: format!("all tracked tasks succeeded (attempt {attempts_used})"),
                });
            }
            Ok(AttemptOutcome::TaskFailed(reason)) => {
                twarn!("am", "{} attempt {attempts_used} failed: {reason}", am.app);
                last_error = reason;
                // Paper §2.2: tear down remaining tasks, re-request, relaunch.
                teardown_attempt(am, attempts_used);
            }
            Ok(AttemptOutcome::AmKilled) => {
                teardown_attempt(am, attempts_used);
                return Ok(JobResult {
                    succeeded: false,
                    attempts_used,
                    diagnostics: "AM container killed".to_string(),
                });
            }
            Err(e) => {
                last_error = format!("{e:#}");
                teardown_attempt(am, attempts_used);
            }
        }
    }
    am.state.set_phase(JobPhase::Failed);
    Ok(JobResult {
        succeeded: false,
        attempts_used,
        diagnostics: format!("exhausted {} attempts; last error: {last_error}", job.max_attempts),
    })
}

/// Priority encodes the task type so RM grants can be matched back to the
/// request that produced them (YARN matches on priority + resource).
fn type_priority(job: &JobSpec, ty: &str) -> u8 {
    let idx = job.task_types.iter().position(|t| t.name == ty).unwrap_or(0);
    (idx as u8) + 2
}

fn priority_type(job: &JobSpec, prio: u8) -> Option<String> {
    let idx = prio.checked_sub(2)? as usize;
    job.task_types.get(idx).map(|t| t.name.clone())
}

fn run_attempt(
    am: &AmContext,
    ctx: &ContainerCtx,
    am_addr: &HostPort,
    attempt: u32,
) -> Result<AttemptOutcome> {
    let job = &am.job;
    let rm = &am.rm;

    // ---- 1. negotiate containers (heterogeneous asks) ----
    let asks: Vec<_> = job
        .task_types
        .iter()
        .map(|t| {
            let mut req = t.to_request();
            req.priority = type_priority(job, &t.name);
            req
        })
        .collect();
    let mut next_index: BTreeMap<String, u32> =
        job.task_types.iter().map(|t| (t.name.clone(), 0u32)).collect();
    let mut launched = 0u32;
    let total = job.total_tasks();
    let mut first_alloc = true;

    let hb_interval = Duration::from_millis(job.heartbeat_ms.max(5));
    let liveness_budget =
        Duration::from_millis(job.heartbeat_ms.max(5) * job.max_missed_heartbeats as u64);
    let attempt_start = Instant::now();
    // Generous ceiling: PJRT compilation dominates task start; scale with
    // model size via a conf knob.
    let launch_timeout =
        Duration::from_millis(job.conf.get_u64("tony.task.launch-timeout-ms", 120_000));

    loop {
        if ctx.killed() {
            return Ok(AttemptOutcome::AmKilled);
        }
        // ---- allocate heartbeat: new grants + completed containers ----
        let resp = rm.allocate(am.app, if first_alloc { &asks } else { &[] }, &[])?;
        first_alloc = false;

        for container in resp.allocated {
            let Some(ty) = priority_type(job, container.priority) else {
                twarn!("am", "grant with unknown priority {}", container.priority);
                continue;
            };
            let index = {
                let slot = next_index.get_mut(&ty).unwrap();
                let i = *slot;
                *slot += 1;
                i
            };
            let task = TaskId::new(ty.clone(), index);
            launch_executor(am, am_addr, attempt, &container, &task)?;
            launched += 1;
            tdebug!(
                "am",
                "{} launched {task} in {} on {} ({launched}/{total})",
                am.app,
                container.id,
                container.node
            );
        }

        // ---- container-level failures (incl. node loss) ----
        for status in resp.completed {
            if let Some(task) = am.state.task_for_container(status.id) {
                let record_exit = am.state.task_exit(&task);
                match status.exit {
                    ExitStatus::Success => {}
                    bad => {
                        // If the task already reported success via RPC this
                        // is benign teardown noise; otherwise it's a failure.
                        if record_exit != Some(0) {
                            return Ok(AttemptOutcome::TaskFailed(format!(
                                "container for {task} exited: {bad:?}"
                            )));
                        }
                    }
                }
            }
        }

        // ---- spec construction once everyone registered ----
        am.state.try_build_spec(attempt);

        // ---- RPC-reported task exits ----
        if let Some((task, code)) = am.state.first_tracked_failure(job) {
            return Ok(AttemptOutcome::TaskFailed(format!("{task} exited with code {code}")));
        }
        if am.state.all_tracked_succeeded(job) {
            tinfo!("am", "{} all tracked tasks succeeded; stopping services", am.app);
            stop_untracked(am, job);
            return Ok(AttemptOutcome::Succeeded);
        }

        // ---- liveness: registration + heartbeat staleness ----
        if launched < total && attempt_start.elapsed() > launch_timeout {
            return Ok(AttemptOutcome::TaskFailed(format!(
                "only {launched}/{total} containers granted within {launch_timeout:?} \
                 (cluster too busy or labels unsatisfiable)"
            )));
        }
        if let Some(task) = am.state.stale_task(liveness_budget) {
            return Ok(AttemptOutcome::TaskFailed(format!(
                "{task} missed {} heartbeats",
                job.max_missed_heartbeats
            )));
        }

        std::thread::sleep(hb_interval.min(Duration::from_millis(20)));
    }
}

fn launch_executor(
    am: &AmContext,
    am_addr: &HostPort,
    attempt: u32,
    container: &Container,
    task: &TaskId,
) -> Result<()> {
    let params = ExecutorParams {
        am_addr: am_addr.clone(),
        job: am.job.clone(),
        preset_dir: am.preset_dir.clone(),
        task: task.clone(),
        spec_version: attempt,
    };
    am.state.record_launch(task.clone(), container.id);
    // The launch-context env mirrors what real TonY sets before exec-ing
    // the executor; the executor re-reads these rather than trusting the
    // closure, keeping the env the source of truth.
    let mut env = BTreeMap::new();
    env.insert("TASK_TYPE".to_string(), task.job_type.clone());
    env.insert("TASK_INDEX".to_string(), task.index.to_string());
    env.insert("AM_ADDR".to_string(), am_addr.to_string());
    env.insert("SPEC_VERSION".to_string(), attempt.to_string());
    am.rm
        .start_container(container, env, Box::new(move |cctx| run_task_executor(cctx, params)))
        .with_context(|| format!("starting executor for {task}"))
}

/// Ask every untracked service task (PS, evaluator) to stop, then give
/// them a moment to exit cleanly.
fn stop_untracked(am: &AmContext, job: &JobSpec) {
    am.state.command_all_untracked(job, AmCommand::Stop);
    let deadline = Instant::now() + Duration::from_secs(3);
    while Instant::now() < deadline {
        if am.state.all_untracked_done(job) {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    // Hard-stop stragglers via the NM.
    for cid in am.state.live_containers() {
        am.rm.stop_container(cid);
    }
}

/// Tear down every container of the current attempt and wait for the dust
/// to settle so the next attempt starts from a clean slate.
fn teardown_attempt(am: &AmContext, attempt: u32) {
    am.state.set_phase(JobPhase::Restarting);
    let containers = am.state.live_containers();
    tinfo!("am", "{} tearing down attempt {attempt} ({} containers)", am.app, containers.len());
    for cid in &containers {
        am.rm.stop_container(*cid);
    }
    // Drain completion events so released capacity is visible before we
    // re-request (avoids double-booking the cluster).
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        let resp = match am.rm.allocate(am.app, &[], &[]) {
            Ok(r) => r,
            Err(_) => break,
        };
        for st in resp.completed {
            am.state.forget_container(st.id);
        }
        if am.state.live_containers().is_empty() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}
