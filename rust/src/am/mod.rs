//! The TonY ApplicationMaster (paper §2.2) — the heart of the system.
//!
//! Responsibilities, extending the paper's fault-tolerance loop with
//! surgical per-task recovery:
//!
//! 1. negotiate with the RM for all task containers, with heterogeneous
//!    requests per task type (GPU workers, CPU-only PS); in gang mode
//!    (`tony.scheduler.gang-mode`, the default) the initial wave and
//!    every recovery wave travel in one allocate round each, which the
//!    RM places **all-or-nothing** — no partial-gang deadlocks under
//!    contention; grants that match no pending task are released back to
//!    the RM, never leaked;
//! 2. launch a TaskExecutor in every granted container;
//! 3. collect each TaskExecutor's (host, port) registration; when all
//!    have registered, construct the **global cluster spec** and hand it
//!    back to every executor;
//! 4. monitor heartbeats, registration deadlines, and task exit
//!    statuses;
//! 5. on a tracked-task failure (node loss, or a `Preempted` exit when
//!    the RM clawed capacity back for a starved queue): re-request
//!    containers *only* for the dead tasks, relaunch them at a bumped
//!    spec version,
//!    patch the cluster spec in place, and push it to the surviving
//!    executors over the heartbeat channel (`AmCommand::Reconfigure`) —
//!    survivors rejoin at the new version without their containers ever
//!    stopping; replacements restore from the last checkpoint;
//! 6. escalate to the paper's full teardown-and-relaunch only after
//!    `tony.task.max-restarts` surgical recoveries fail within one
//!    attempt;
//! 7. report the first worker's UI URL + task logs to the client via the
//!    RM tracking URL.

pub mod protocol;
pub mod state;

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::executor::{run_task_executor, ExecutorParams};
use crate::net::rpc::RpcServer;
use crate::tonyconf::JobSpec;
use crate::util::event::{tag, TimerWheel};
use crate::util::ids::{ApplicationId, ContainerId, TaskId};
use crate::util::HostPort;
use crate::yarn::{Container, ContainerCtx, ContainerRequest, ExitStatus, ResourceManager};
use crate::{tdebug, tinfo, twarn};

pub use protocol::{AmCommand, FinishedMsg, HeartbeatMsg, HeartbeatReply, RegisterMsg};
pub use state::{AmState, AttemptOutcome, JobPhase, TaskRecord};

/// Result of one whole AM run (exposed for tests/portal).
#[derive(Debug, Clone, PartialEq)]
pub struct JobResult {
    pub succeeded: bool,
    pub attempts_used: u32,
    pub diagnostics: String,
}

/// Everything the AM needs besides the RM connection.
pub struct AmContext {
    pub rm: Arc<ResourceManager>,
    pub app: ApplicationId,
    pub job: Arc<JobSpec>,
    pub preset_dir: PathBuf,
    /// Shared state — the portal reads this concurrently.
    pub state: Arc<AmState>,
}

/// Run the ApplicationMaster to completion.  Returns the container exit
/// code (0 = job succeeded within the attempt budget).
pub fn run_application_master(am: AmContext, ctx: &ContainerCtx) -> i32 {
    let code = match am_body(&am, ctx) {
        Ok(result) => {
            am.rm
                .finish_application(am.app, result.succeeded, &result.diagnostics);
            if result.succeeded {
                0
            } else {
                1
            }
        }
        Err(e) => {
            twarn!("am", "{} AM error: {e:#}", am.app);
            am.rm.finish_application(am.app, false, &format!("AM error: {e:#}"));
            1
        }
    };
    // Close every stage still open so the trace's wall-clock accounting
    // ends with the job (the gateway's finalize is a no-op after this).
    if let Some(t) = am.state.trace() {
        t.end_all();
    }
    code
}

fn am_body(am: &AmContext, ctx: &ContainerCtx) -> Result<JobResult> {
    let job = &am.job;
    am.rm.register_am(am.app, None).context("registering AM")?;

    // Event wiring: the AM monitor loop blocks on the state's wakeup bus.
    // Producers: the RM (grants, completed containers, app-state changes,
    // fallback ticks), the executor-facing RPC handler (registrations,
    // task exits, spec builds, version acks), and the AM container's own
    // kill switch.
    let bus = am.state.events().clone();
    am.rm.register_am_waker(am.app, &bus);
    ctx.kill_switch().register(&bus);

    // The AM's RPC endpoint that all TaskExecutors talk to.
    let server = RpcServer::serve(Arc::new(state::AmRpcHandler::new(am.state.clone())))
        .map_err(|e| anyhow::anyhow!("am rpc server: {e}"))?;
    let am_addr = server.addr();
    tinfo!("am", "{} AM up at {am_addr}; job '{}' ({} tasks)", am.app, job.name, job.total_tasks());

    let mut attempts_used = 0;
    let mut last_error = String::new();
    while attempts_used < job.max_attempts {
        attempts_used += 1;
        am.state.begin_attempt(attempts_used);
        // Elastic jobs (re-)advertise their resize bounds each attempt:
        // a teardown relaunches the original worker count, so the
        // scheduler's acknowledged `current` must reset with it (this
        // also clears any resize left in flight by the dead attempt).
        // Rigid jobs (min == max) never register, so the elasticity
        // pass cannot touch them.
        if job.is_elastic() {
            if let Some(w) = job.task_type(crate::tonyconf::WORKER) {
                am.rm
                    .register_elastic(
                        am.app,
                        w.resource.clone(),
                        w.node_label.clone(),
                        job.workers_min,
                        job.workers_max,
                        w.instances,
                    )
                    .context("registering elastic bounds")?;
            }
        }
        tinfo!("am", "{} attempt {attempts_used}/{}", am.app, job.max_attempts);
        match run_attempt(am, ctx, &am_addr, attempts_used) {
            Ok(AttemptOutcome::Succeeded) => {
                am.state.set_phase(JobPhase::Succeeded);
                return Ok(JobResult {
                    succeeded: true,
                    attempts_used,
                    diagnostics: format!("all tracked tasks succeeded (attempt {attempts_used})"),
                });
            }
            Ok(AttemptOutcome::TaskFailed(reason)) => {
                twarn!("am", "{} attempt {attempts_used} failed: {reason}", am.app);
                last_error = reason;
                // Escalation (paper §2.2): tear down remaining tasks,
                // re-request, relaunch the whole attempt.
                teardown_attempt(am, attempts_used);
            }
            Ok(AttemptOutcome::AmKilled) => {
                teardown_attempt(am, attempts_used);
                return Ok(JobResult {
                    succeeded: false,
                    attempts_used,
                    diagnostics: "AM container killed".to_string(),
                });
            }
            Err(e) => {
                last_error = format!("{e:#}");
                teardown_attempt(am, attempts_used);
            }
        }
    }
    am.state.set_phase(JobPhase::Failed);
    Ok(JobResult {
        succeeded: false,
        attempts_used,
        diagnostics: format!("exhausted {} attempts; last error: {last_error}", job.max_attempts),
    })
}

/// Priority encodes the task type so RM grants can be matched back to the
/// request that produced them (YARN matches on priority + resource).
fn type_priority(job: &JobSpec, ty: &str) -> u8 {
    let idx = job.task_types.iter().position(|t| t.name == ty).unwrap_or(0);
    (idx as u8) + 2
}

fn priority_type(job: &JobSpec, prio: u8) -> Option<String> {
    let idx = prio.checked_sub(2)? as usize;
    job.task_types.get(idx).map(|t| t.name.clone())
}

/// Matches RM grants back to the tasks awaiting (re)launch, accumulates
/// the container asks those tasks need, and queues unmatched grants for
/// release.  Centralizing this is what fixes the historical leak where a
/// grant with an unknown priority was logged and dropped — its node
/// capacity stayed booked for the life of the job.
struct GrantRouter {
    /// task type -> indices awaiting (re)launch, FIFO.
    pending: BTreeMap<String, VecDeque<u32>>,
    /// Instances enqueued since the last `take_asks` (per type).
    unasked: BTreeMap<String, u32>,
    /// Grants to hand back on the next allocate call.
    releases: Vec<ContainerId>,
}

impl GrantRouter {
    fn new(job: &JobSpec) -> GrantRouter {
        let mut pending = BTreeMap::new();
        let mut unasked = BTreeMap::new();
        for t in &job.task_types {
            pending.insert(t.name.clone(), (0..t.instances).collect::<VecDeque<u32>>());
            unasked.insert(t.name.clone(), t.instances);
        }
        GrantRouter { pending, unasked, releases: Vec::new() }
    }

    /// Tasks granted nothing yet (still awaiting a container).
    fn outstanding(&self) -> usize {
        self.pending.values().map(|q| q.len()).sum()
    }

    /// Queue a task for relaunch (surgical recovery).
    fn enqueue(&mut self, task: &TaskId) {
        self.pending
            .entry(task.job_type.clone())
            .or_default()
            .push_back(task.index);
        *self.unasked.entry(task.job_type.clone()).or_insert(0) += 1;
    }

    /// Container asks covering everything enqueued since the last call.
    fn take_asks(&mut self, job: &JobSpec) -> Vec<ContainerRequest> {
        let mut asks = Vec::new();
        for (ty, n) in self.unasked.iter_mut() {
            if *n == 0 {
                continue;
            }
            if let Some(t) = job.task_type(ty) {
                let mut req = t.to_request();
                req.count = *n;
                req.priority = type_priority(job, ty);
                asks.push(req);
            }
            *n = 0;
        }
        asks
    }

    /// Match a grant to a pending task.  A grant whose priority maps to
    /// no task type — or to a type with nothing pending (surplus) — is
    /// queued for release instead of leaking its node capacity.
    fn route(&mut self, job: &JobSpec, container: &Container) -> Option<TaskId> {
        if let Some(ty) = priority_type(job, container.priority) {
            if let Some(idx) = self.pending.get_mut(&ty).and_then(|q| q.pop_front()) {
                return Some(TaskId::new(ty, idx));
            }
        }
        self.releases.push(container.id);
        None
    }

    /// Grants to release via the next allocate call.
    fn take_releases(&mut self) -> Vec<ContainerId> {
        std::mem::take(&mut self.releases)
    }
}

fn run_attempt(
    am: &AmContext,
    ctx: &ContainerCtx,
    am_addr: &HostPort,
    attempt: u32,
) -> Result<AttemptOutcome> {
    let job = &am.job;
    let rm = &am.rm;

    let mut router = GrantRouter::new(job);
    let total = job.total_tasks();
    let mut launched = 0u32;

    let clock = am.state.clock().clone();
    let bus = am.state.events().clone();
    let hb_interval = Duration::from_millis(job.heartbeat_ms.max(5));
    let liveness_budget =
        Duration::from_millis(job.heartbeat_ms.max(5) * job.max_missed_heartbeats as u64);
    // Generous ceilings: PJRT compilation dominates task start; scale
    // with model size via conf knobs.
    let launch_timeout =
        Duration::from_millis(job.conf.get_u64("tony.task.launch-timeout-ms", 120_000));
    let registration_timeout =
        Duration::from_millis(job.conf.get_u64("tony.task.registration-timeout-ms", 120_000));
    // Surgical-recovery budget per attempt; 0 restores the paper's pure
    // teardown-everything behaviour.
    let max_task_restarts = job.conf.get_u64("tony.task.max-restarts", 3) as u32;
    let mut surgical_used = 0u32;
    // Cluster/queue gauge sampling cadence (avoids taking the RM lock
    // every monitor tick; the registry rate-limits appends as well).
    let gauge_interval = job.metrics.sample_interval_ms.max(1);
    let mut last_gauge_sample: Option<u64> = None;
    // Start of the current negotiation or recovery window (relaunch
    // grants must arrive within `launch_timeout` of this).
    let mut phase_started = clock.now_ms();
    let mut recovering = false;
    // Elastic resize command awaiting a quiet point.  Captured from the
    // allocate response but acted on only when no recovery is in flight,
    // no failures were collected this tick, and no grants are
    // outstanding — a resize wave must never interleave with surgical
    // recovery, and a stale router entry could otherwise resurrect a
    // removed task's record via `record_launch`.  While deferred, the
    // RM's in-flight entry stays alive, keeping further elasticity (and
    // preemption, for shrinks) stood down.
    let mut pending_resize: Option<u32> = None;

    // The event machinery replacing the old ≤20 ms sleep-poll: every
    // deadline the loop's checks depend on is armed on the wheel, the
    // wheel's next deadline (capped by the fallback tick) bounds the bus
    // wait, and the loop otherwise runs only when an event arrives.
    // `tony.event.poll-mode` restores interval polling for A/B benches.
    let fallback_tick_ms = job.conf.get_u64("tony.am.fallback-tick-ms", 500).max(1);
    let poll_mode = job.conf.get("tony.event.poll-mode").map(|v| v == "true").unwrap_or(false);
    let wheel = TimerWheel::new(
        clock.clone(),
        job.conf.get_u64("tony.event.timer-capacity", 4096) as usize,
    );
    let mut armed: Vec<crate::util::event::TimerId> = Vec::new();

    loop {
        am.state.note_loop_iter();
        if ctx.killed() {
            return Ok(AttemptOutcome::AmKilled);
        }
        // ---- allocate heartbeat: asks + releases in, grants + completed
        //      containers out ----
        let asks = router.take_asks(job);
        let releases = router.take_releases();
        if !releases.is_empty() {
            am.state.note_released_grants(releases.len() as u64);
        }
        let resp = rm.allocate(am.app, &asks, &releases)?;

        // Preemption notices: the RM will kill these containers after
        // the grace period to restore another queue's guarantee.  The
        // `Preempted` exits that follow are absorbed below exactly like
        // node loss — surgical recovery re-requests just those tasks (as
        // a fresh gang) while survivors keep running.
        if !resp.preempt_notices.is_empty() {
            twarn!(
                "am",
                "{} preemption notice for {} container(s); replacements follow via recovery",
                am.app,
                resp.preempt_notices.len()
            );
        }

        // Elastic resize command: the RM wants this job to converge to
        // `target` workers.  At most one is in flight per job, so a new
        // command simply supersedes an unapplied one.
        if let Some(target) = resp.resize_target {
            tinfo!("am", "{} resize command: converge to {target} worker(s)", am.app);
            pending_resize = Some(target);
        }

        for container in resp.allocated {
            let Some(task) = router.route(job, &container) else {
                twarn!(
                    "am",
                    "{} grant {} (priority {}) matches no pending task; releasing",
                    am.app,
                    container.id,
                    container.priority
                );
                continue;
            };
            match launch_executor(am, am_addr, &container, &task) {
                Ok(()) => {
                    launched += 1;
                    tdebug!(
                        "am",
                        "{} launched {task} in {} on {} ({launched}/{total})",
                        am.app,
                        container.id,
                        container.node
                    );
                }
                Err(e) => {
                    // Node died between grant and start: drop the corpse
                    // and re-ask instead of burning the whole attempt.
                    twarn!("am", "{} launch of {task} failed: {e:#}; re-requesting", am.app);
                    am.state.forget_container(container.id);
                    router.enqueue(&task);
                }
            }
        }

        // ---- collect this tick's failures ----
        let mut failed: BTreeMap<TaskId, String> = BTreeMap::new();
        // Tasks lost to capacity preemption this tick: they recover
        // through the same surgical path but do NOT consume the restart
        // budget — preemption is RM policy, not a task fault.
        let mut preempted_tasks: std::collections::BTreeSet<TaskId> =
            std::collections::BTreeSet::new();

        // Container-level failures (incl. node loss).
        for status in resp.completed {
            if let Some(task) = am.state.task_for_container(status.id) {
                let record_exit = am.state.task_exit(&task);
                match status.exit {
                    ExitStatus::Success => {
                        am.state.forget_container(status.id);
                    }
                    ExitStatus::Released => {
                        // Elastic shrink hand-back.  Normally the AM has
                        // already removed the task's record (so
                        // `task_for_container` misses and we never get
                        // here); defensively absorb it with no failure
                        // entry either way — a release is never a fault.
                        am.state.forget_container(status.id);
                    }
                    bad => {
                        // If the task already reported success via RPC
                        // this is benign teardown noise; otherwise it's a
                        // failure.
                        if bad == ExitStatus::Preempted {
                            am.state.note_preempted();
                        }
                        if record_exit == Some(0) {
                            am.state.forget_container(status.id);
                        } else {
                            if bad == ExitStatus::Preempted {
                                preempted_tasks.insert(task.clone());
                            }
                            failed
                                .entry(task.clone())
                                .or_insert_with(|| format!("container for {task} exited: {bad:?}"));
                        }
                    }
                }
            }
        }

        // ---- sampled cluster/queue gauges (per-queue dominant-share
        //      utilization, pending asks, per-dimension usage) ----
        let now = clock.now_ms();
        if am.state.metrics_registry().enabled()
            && last_gauge_sample.map_or(true, |t| now.saturating_sub(t) >= gauge_interval)
        {
            last_gauge_sample = Some(now);
            let registry = am.state.metrics_registry();
            for q in rm.queue_stats() {
                registry.observe_queue(&q.name, q.utilization, q.used, q.pending);
            }
        }

        // ---- spec construction once everyone registered ----
        am.state.try_build_spec(am.state.spec_version());

        // RPC-reported task exits.
        if let Some((task, code)) = am.state.first_tracked_failure(job) {
            failed
                .entry(task.clone())
                .or_insert_with(|| format!("{task} exited with code {code}"));
        }

        if failed.is_empty() && am.state.all_tracked_succeeded(job) {
            tinfo!("am", "{} all tracked tasks succeeded; stopping services", am.app);
            stop_untracked(am, job);
            return Ok(AttemptOutcome::Succeeded);
        }

        // ---- liveness: heartbeat staleness + registration deadline ----
        if let Some(task) = am.state.stale_task(liveness_budget) {
            failed.entry(task.clone()).or_insert_with(|| {
                format!("{task} missed {} heartbeats", job.max_missed_heartbeats)
            });
        }
        if let Some(task) = am.state.unregistered_task(registration_timeout) {
            failed.entry(task.clone()).or_insert_with(|| {
                format!(
                    "{task} launched but never registered within {registration_timeout:?}"
                )
            });
        }

        // ---- elastic resize wave (docs/SCHEDULING.md "Elasticity") ----
        // Both directions reuse the surgical-recovery machinery: bump
        // the spec version, rebuild the cluster spec, let survivors
        // resync via `Reconfigure`, and acknowledge completion to the
        // RM through `note_resized` once the wave settles.
        if pending_resize.is_some()
            && !recovering
            && failed.is_empty()
            && router.outstanding() == 0
        {
            let target = pending_resize.take().expect("checked is_some");
            let cur = am.state.expected_workers();
            if target > cur {
                let new_tasks: Vec<TaskId> = (cur..target)
                    .map(|i| TaskId::new(crate::tonyconf::WORKER, i))
                    .collect();
                let version = am.state.begin_grow(&new_tasks);
                for t in &new_tasks {
                    router.enqueue(t);
                }
                tinfo!(
                    "am",
                    "{} elastic grow {cur} -> {target} worker(s) at spec v{version}",
                    am.app
                );
                recovering = true;
                phase_started = clock.now_ms();
                // The delta-gang asks only travel on the next allocate
                // call at the top of the loop.
                continue;
            } else if target < cur {
                let (version, removed) = am.state.begin_shrink(cur - target);
                let cids: Vec<ContainerId> =
                    removed.iter().filter_map(|(_, c)| *c).collect();
                let names: Vec<String> =
                    removed.iter().map(|(t, _)| t.to_string()).collect();
                // The RM marks these before killing so their exits come
                // back `Released`, not `Killed` — never a task fault.
                rm.release_workers(am.app, &cids);
                am.state.try_build_spec(version);
                tinfo!(
                    "am",
                    "{} elastic shrink {cur} -> {target}: releasing [{}] at spec v{version}",
                    am.app,
                    names.join(", ")
                );
                recovering = true;
                phase_started = clock.now_ms();
                continue;
            } else {
                // Already at target (e.g. the command raced an attempt
                // restart back to the original count): just acknowledge.
                rm.note_resized(am.app, cur);
            }
        }

        // ---- surgical recovery (or escalation) ----
        if !failed.is_empty() {
            let summary = failed
                .iter()
                .map(|(_, reason)| reason.clone())
                .collect::<Vec<_>>()
                .join("; ");
            // A tick whose failures are all `Preempted` exits is the RM
            // reclaiming capacity for a starved queue, not the job
            // misbehaving: recover, but leave the restart budget alone
            // (otherwise routine preemption would eventually "fail" a
            // perfectly healthy job).
            let only_preempted = failed.keys().all(|t| preempted_tasks.contains(t));
            if !only_preempted {
                if surgical_used >= max_task_restarts {
                    return Ok(AttemptOutcome::TaskFailed(format!(
                        "{summary} (surgical restart budget {max_task_restarts} exhausted)"
                    )));
                }
                surgical_used += 1;
            }
            let dead: Vec<TaskId> = failed.keys().cloned().collect();
            recover_tasks(am, &mut router, &dead, surgical_used, max_task_restarts);
            recovering = true;
            phase_started = clock.now_ms();
            continue;
        }

        // ---- progress deadlines ----
        let now = clock.now_ms();
        if router.outstanding() > 0
            && now.saturating_sub(phase_started) > launch_timeout.as_millis() as u64
        {
            if rm.app_sched_state(am.app) == crate::yarn::AppSchedState::WaitingForGang {
                // Waiting *whole* behind running waves is gang mode's
                // healthy serialize-instead-of-deadlock state, not a
                // stuck negotiation: extend the window instead of
                // burning an attempt.  A gang that can never place gets
                // demoted by the scheduler (its singles then time out
                // here normally), and the gateway's job timeout remains
                // the overall backstop.
                tdebug!(
                    "am",
                    "{} wave still WAITING_FOR_GANG after {launch_timeout:?}; extending",
                    am.app
                );
                phase_started = now;
            } else {
                return Ok(AttemptOutcome::TaskFailed(format!(
                    "{} container(s) not granted within {launch_timeout:?} \
                     (cluster too busy or labels unsatisfiable)",
                    router.outstanding()
                )));
            }
        }
        let recovery_budget_ms = (launch_timeout + registration_timeout).as_millis() as u64;
        if recovering {
            if am.state.recovery_complete() {
                recovering = false;
                am.state.set_phase(JobPhase::Running);
                tinfo!(
                    "am",
                    "{} surgical recovery complete at spec v{} (attempt {attempt})",
                    am.app,
                    am.state.spec_version()
                );
                // Report the (possibly unchanged) worker count so the RM
                // clears its in-flight resize entry, stamps the grow
                // cooldown, and re-runs the scheduler.  Skipped while a
                // resize is still deferred locally — the wave it starts
                // will acknowledge with the final count instead.
                if job.is_elastic() && pending_resize.is_none() {
                    rm.note_resized(am.app, am.state.expected_workers());
                }
            } else if now.saturating_sub(phase_started) > recovery_budget_ms {
                return Ok(AttemptOutcome::TaskFailed(
                    "surgical recovery timed out (survivors never acked the patched spec)"
                        .to_string(),
                ));
            }
        }

        if poll_mode {
            // A/B baseline: the paper-era fixed-interval poll.
            clock.sleep(hb_interval.min(Duration::from_millis(20)));
            continue;
        }

        // ---- block until the next event or the earliest deadline ----
        // Re-arm the wheel from scratch each pass: the deadline set is
        // tiny (≤4) and most passes change it (heartbeats refresh
        // liveness, grants clear the launch window).
        for id in armed.drain(..) {
            wheel.cancel(id);
        }
        let _ = wheel.poll_tags(); // clear anything that fired mid-pass
        if let Some(d) = am.state.next_liveness_deadline(liveness_budget, registration_timeout)
        {
            armed.extend(wheel.arm_at(d.saturating_add(1), tag::TICK));
        }
        if router.outstanding() > 0 {
            let d = phase_started.saturating_add(launch_timeout.as_millis() as u64 + 1);
            armed.extend(wheel.arm_at(d, tag::TICK));
        }
        if recovering {
            armed.extend(wheel.arm_at(phase_started.saturating_add(recovery_budget_ms + 1), tag::TICK));
        }
        if pending_resize.is_some() {
            // A deferred resize must get another pass shortly after the
            // blocking condition clears; don't rely on the fallback tick.
            armed.extend(wheel.arm_at(
                now.saturating_add((hb_interval.as_millis() as u64).max(1)),
                tag::TICK,
            ));
        }
        if am.state.metrics_registry().enabled() {
            let d = last_gauge_sample.unwrap_or(now).saturating_add(gauge_interval);
            armed.extend(wheel.arm_at(d, tag::TICK));
        }
        let now = clock.now_ms();
        let deadline = wheel
            .next_deadline()
            .unwrap_or(u64::MAX)
            .min(now.saturating_add(fallback_tick_ms));
        let fired = bus.wait_until(&*clock, deadline);
        let _ = wheel.poll_tags();
        if fired != 0 {
            tdebug!("am", "{} woke on [{}]", am.app, tag::names(fired));
        }
    }
}

/// Begin a surgical recovery for `dead`: stop their old containers, bump
/// the spec version, and queue replacements for relaunch.  Survivors are
/// untouched — they learn the new spec via `Reconfigure` on their next
/// heartbeat once the replacements have registered.
fn recover_tasks(
    am: &AmContext,
    router: &mut GrantRouter,
    dead: &[TaskId],
    used: u32,
    budget: u32,
) {
    // Capture the corpses before the records are reset.
    let doomed: Vec<ContainerId> =
        dead.iter().filter_map(|t| am.state.container_of(t)).collect();
    let version = am.state.begin_recovery(dead);
    for cid in &doomed {
        am.rm.stop_container(*cid);
    }
    for task in dead {
        router.enqueue(task);
    }
    let names: Vec<String> = dead.iter().map(|t| t.to_string()).collect();
    twarn!(
        "am",
        "{} surgical recovery {used}/{budget}: relaunching [{}] at spec v{version}; \
         survivors keep running",
        am.app,
        names.join(", ")
    );
}

fn launch_executor(
    am: &AmContext,
    am_addr: &HostPort,
    container: &Container,
    task: &TaskId,
) -> Result<()> {
    let spec_version = am.state.spec_version();
    let params = ExecutorParams {
        am_addr: am_addr.clone(),
        job: am.job.clone(),
        preset_dir: am.preset_dir.clone(),
        task: task.clone(),
        spec_version,
        clock: am.state.clock().clone(),
        app: am.app,
    };
    am.state.record_launch(task.clone(), container.id);
    // The launch-context env mirrors what real TonY sets before exec-ing
    // the executor; the executor re-reads these rather than trusting the
    // closure, keeping the env the source of truth.
    let mut env = BTreeMap::new();
    env.insert("TASK_TYPE".to_string(), task.job_type.clone());
    env.insert("TASK_INDEX".to_string(), task.index.to_string());
    env.insert("AM_ADDR".to_string(), am_addr.to_string());
    env.insert("SPEC_VERSION".to_string(), spec_version.to_string());
    am.rm
        .start_container(container, env, Box::new(move |cctx| run_task_executor(cctx, params)))
        .with_context(|| format!("starting executor for {task}"))
}

/// Ask every untracked service task (PS, evaluator) to stop, then give
/// them a moment to exit cleanly.  Waits on the AM bus: each service's
/// final `AM_FINISHED` wakes this immediately (`tag::TASK_EXIT`).
fn stop_untracked(am: &AmContext, job: &JobSpec) {
    am.state.command_all_untracked(job, AmCommand::Stop);
    let clock = am.state.clock().clone();
    let bus = am.state.events().clone();
    let deadline = clock.now_ms().saturating_add(3_000);
    while clock.now_ms() < deadline {
        if am.state.all_untracked_done(job) {
            return;
        }
        bus.wait_until(&*clock, deadline);
    }
    // Hard-stop stragglers via the NM.
    for cid in am.state.live_containers() {
        am.rm.stop_container(cid);
    }
}

/// Tear down every container of the current attempt and wait for the dust
/// to settle so the next attempt starts from a clean slate.
fn teardown_attempt(am: &AmContext, attempt: u32) {
    am.state.set_phase(JobPhase::Restarting);
    let containers = am.state.live_containers();
    tinfo!("am", "{} tearing down attempt {attempt} ({} containers)", am.app, containers.len());
    for cid in &containers {
        am.rm.stop_container(*cid);
    }
    // Drain completion events so released capacity is visible before we
    // re-request (avoids double-booking the cluster).  Each container's
    // completion callback notifies the AM waker (`tag::COMPLETED`), so
    // this blocks on the bus instead of re-polling allocate every 10 ms.
    let clock = am.state.clock().clone();
    let bus = am.state.events().clone();
    let deadline = clock.now_ms().saturating_add(10_000);
    while clock.now_ms() < deadline {
        let resp = match am.rm.allocate(am.app, &[], &[]) {
            Ok(r) => r,
            Err(_) => break,
        };
        for st in resp.completed {
            am.state.forget_container(st.id);
        }
        if am.state.live_containers().is_empty() {
            break;
        }
        bus.wait_until(&*clock, deadline);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::JobConfBuilder;
    use crate::util::ids::ApplicationId;
    use crate::yarn::Resource;

    fn job() -> Arc<JobSpec> {
        let conf = JobConfBuilder::new("router")
            .instances("worker", 2)
            .instances("ps", 1)
            .build();
        Arc::new(JobSpec::from_conf(&conf).unwrap())
    }

    fn grant(app: ApplicationId, seq: u64, priority: u8) -> Container {
        Container {
            id: ContainerId { app, seq },
            app,
            node: crate::util::ids::NodeId(0),
            resource: Resource::new(1024, 1, 0),
            priority,
        }
    }

    #[test]
    fn router_routes_known_priorities_in_order() {
        let job = job();
        let mut router = GrantRouter::new(&job);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        assert_eq!(router.outstanding(), 3);
        let asks = router.take_asks(&job);
        assert_eq!(asks.len(), 2, "one ask per task type");
        assert!(router.take_asks(&job).is_empty(), "asks are consumed");

        // worker priority = 2, ps priority = 3 (index + 2).
        assert_eq!(router.route(&job, &grant(app, 1, 2)), Some(TaskId::new("worker", 0)));
        assert_eq!(router.route(&job, &grant(app, 2, 3)), Some(TaskId::new("ps", 0)));
        assert_eq!(router.route(&job, &grant(app, 3, 2)), Some(TaskId::new("worker", 1)));
        assert_eq!(router.outstanding(), 0);
        assert!(router.take_releases().is_empty());
    }

    #[test]
    fn router_releases_unknown_and_surplus_grants() {
        // Regression for the container leak: a grant whose priority maps
        // to no task type used to be logged and dropped, leaking its
        // node capacity for the life of the job.  It must be queued for
        // release via the next allocate call instead.
        let job = job();
        let mut router = GrantRouter::new(&job);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        assert_eq!(router.route(&job, &grant(app, 1, 99)), None);

        // Surplus grant for a known type with nothing pending leaks the
        // same way; it must also be released.
        assert_eq!(router.route(&job, &grant(app, 2, 3)), Some(TaskId::new("ps", 0)));
        assert_eq!(router.route(&job, &grant(app, 3, 3)), None);

        let releases = router.take_releases();
        assert_eq!(releases.len(), 2);
        assert_eq!(releases[0].seq, 1);
        assert_eq!(releases[1].seq, 3);
        assert!(router.take_releases().is_empty(), "releases are consumed");
    }

    #[test]
    fn router_enqueue_reasks_for_replacements() {
        let job = job();
        let mut router = GrantRouter::new(&job);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let _ = router.take_asks(&job);
        for (seq, prio) in [(1, 2), (2, 2), (3, 3)] {
            assert!(router.route(&job, &grant(app, seq, prio)).is_some());
        }
        // worker:1 dies -> enqueue produces exactly one worker ask.
        router.enqueue(&TaskId::new("worker", 1));
        assert_eq!(router.outstanding(), 1);
        let asks = router.take_asks(&job);
        assert_eq!(asks.len(), 1);
        assert_eq!(asks[0].count, 1);
        assert_eq!(asks[0].priority, 2);
        // The replacement grant routes back to worker:1 specifically.
        assert_eq!(router.route(&job, &grant(app, 4, 2)), Some(TaskId::new("worker", 1)));
    }
}
