//! TaskExecutor <-> AM RPC messages (registration, spec fetch, heartbeat,
//! final status) — the control-plane protocol of paper §2.2.

use crate::framework::protocol::TaskMetrics;
use crate::net::wire::{Reader, Wire, WireError, Writer};

pub const AM_REGISTER: u16 = 10;
pub const AM_GET_SPEC: u16 = 11;
pub const AM_HEARTBEAT: u16 = 12;
pub const AM_FINISHED: u16 = 13;
pub const AM_STATUS: u16 = 14;

/// Commands the AM piggybacks on heartbeat responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AmCommand {
    None = 0,
    /// Service task should exit cleanly (job finished).
    Stop = 1,
    /// Task belongs to a dead incarnation; die immediately.
    Abort = 2,
    /// The cluster spec changed underneath a surviving task (surgical
    /// recovery relaunched a peer): re-fetch the spec at the version in
    /// [`HeartbeatReply::spec_version`] and keep running.
    Reconfigure = 3,
}

impl AmCommand {
    pub fn from_u8(v: u8) -> AmCommand {
        match v {
            1 => AmCommand::Stop,
            2 => AmCommand::Abort,
            3 => AmCommand::Reconfigure,
            _ => AmCommand::None,
        }
    }
}

/// Heartbeat response: the command byte first (older readers that only
/// inspect byte 0 still work), then the AM's current cluster-spec
/// version — the payload of a `Reconfigure`, and a cheap consistency
/// signal otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeartbeatReply {
    pub command: AmCommand,
    pub spec_version: u32,
}

impl HeartbeatReply {
    pub fn to_bytes(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(5);
        out.push(self.command as u8);
        out.extend_from_slice(&self.spec_version.to_le_bytes());
        out
    }

    /// Lenient decode: a bare command byte (no version) is accepted so
    /// old-style replies keep parsing.
    pub fn from_bytes(bytes: &[u8]) -> HeartbeatReply {
        let command = AmCommand::from_u8(bytes.first().copied().unwrap_or(0));
        let spec_version = bytes
            .get(1..5)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .unwrap_or(0);
        HeartbeatReply { command, spec_version }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterMsg {
    pub task_type: String,
    pub index: u32,
    pub host: String,
    pub port: u16,
    /// First worker's visualization UI, if it started one (§2.2).
    pub ui_url: Option<String>,
    pub spec_version: u32,
}

impl Wire for RegisterMsg {
    fn encode(&self, w: &mut Writer) {
        w.str(&self.task_type);
        w.u32(self.index);
        w.str(&self.host);
        w.u16(self.port);
        self.ui_url.encode(w);
        w.u32(self.spec_version);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(RegisterMsg {
            task_type: r.str()?,
            index: r.u32()?,
            host: r.str()?,
            port: r.u16()?,
            ui_url: Option::<String>::decode(r)?,
            spec_version: r.u32()?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct GetSpecMsg {
    pub spec_version: u32,
    pub timeout_ms: u64,
}

impl Wire for GetSpecMsg {
    fn encode(&self, w: &mut Writer) {
        w.u32(self.spec_version);
        w.u64(self.timeout_ms);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(GetSpecMsg { spec_version: r.u32()?, timeout_ms: r.u64()? })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HeartbeatMsg {
    pub task_type: String,
    pub index: u32,
    pub spec_version: u32,
    pub metrics: TaskMetrics,
}

impl Wire for HeartbeatMsg {
    fn encode(&self, w: &mut Writer) {
        w.str(&self.task_type);
        w.u32(self.index);
        w.u32(self.spec_version);
        self.metrics.encode(w);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(HeartbeatMsg {
            task_type: r.str()?,
            index: r.u32()?,
            spec_version: r.u32()?,
            metrics: TaskMetrics::decode(r)?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct FinishedMsg {
    pub task_type: String,
    pub index: u32,
    pub spec_version: u32,
    pub exit_code: i64,
}

impl Wire for FinishedMsg {
    fn encode(&self, w: &mut Writer) {
        w.str(&self.task_type);
        w.u32(self.index);
        w.u32(self.spec_version);
        w.i64(self.exit_code);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(FinishedMsg {
            task_type: r.str()?,
            index: r.u32()?,
            spec_version: r.u32()?,
            exit_code: r.i64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let reg = RegisterMsg {
            task_type: "worker".into(),
            index: 2,
            host: "127.0.0.1".into(),
            port: 9999,
            ui_url: Some("http://127.0.0.1:8080".into()),
            spec_version: 1,
        };
        assert_eq!(RegisterMsg::from_bytes(&reg.to_bytes()).unwrap(), reg);

        let hb = HeartbeatMsg {
            task_type: "ps".into(),
            index: 0,
            spec_version: 3,
            metrics: TaskMetrics { step: 5, loss: 1.5, ..Default::default() },
        };
        assert_eq!(HeartbeatMsg::from_bytes(&hb.to_bytes()).unwrap(), hb);

        let fin = FinishedMsg { task_type: "worker".into(), index: 1, spec_version: 2, exit_code: -9 };
        assert_eq!(FinishedMsg::from_bytes(&fin.to_bytes()).unwrap(), fin);

        let gs = GetSpecMsg { spec_version: 1, timeout_ms: 500 };
        assert_eq!(GetSpecMsg::from_bytes(&gs.to_bytes()).unwrap(), gs);
    }

    #[test]
    fn command_codes() {
        assert_eq!(AmCommand::from_u8(0), AmCommand::None);
        assert_eq!(AmCommand::from_u8(1), AmCommand::Stop);
        assert_eq!(AmCommand::from_u8(2), AmCommand::Abort);
        assert_eq!(AmCommand::from_u8(3), AmCommand::Reconfigure);
        assert_eq!(AmCommand::from_u8(77), AmCommand::None);
    }

    #[test]
    fn heartbeat_reply_round_trips() {
        let r = HeartbeatReply { command: AmCommand::Reconfigure, spec_version: 7 };
        assert_eq!(HeartbeatReply::from_bytes(&r.to_bytes()), r);
        // Bare command byte (legacy shape) still decodes.
        let bare = HeartbeatReply::from_bytes(&[AmCommand::Stop as u8]);
        assert_eq!(bare.command, AmCommand::Stop);
        assert_eq!(bare.spec_version, 0);
        assert_eq!(HeartbeatReply::from_bytes(&[]).command, AmCommand::None);
    }
}
