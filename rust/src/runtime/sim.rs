//! Deterministic simulation backend for the engine (default build).
//!
//! The offline build cannot fetch the `xla` PJRT bindings, so this module
//! stands in for them: it "compiles" the same artifact names the AOT
//! pipeline emits (`init_params`, `worker_step`, `eval_loss`, `ps_adam`)
//! and executes them as closed-form host math with the same input/output
//! signatures.  The math is chosen so distributed training *behaves*
//! realistically end-to-end:
//!
//! - `init_params(seed)` draws parameters uniformly from [-1, 1)
//!   (SplitMix64, fully deterministic per seed);
//! - `worker_step(params, batch)` returns
//!   `loss = 0.5 + mean(params²) + jitter(batch)` and `grads = params`
//!   (the gradient of ½‖p‖² — descending it genuinely reduces the loss);
//! - `eval_loss(params, batch)` is the same loss without the batch jitter;
//! - `ps_adam(p, g, m, v, step, lr)` is an exact Adam update with the
//!   hyperparameters from meta.json.
//!
//! So losses are finite, strictly positive, batch-dependent, and decrease
//! as the PS applies updates — which is what the AM/executor/framework
//! layers, Dr. Elephant heuristics, and the gateway benches observe.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::meta::ArtifactMeta;
use super::tensor::Tensor;
use crate::util::SplitMix64;

/// A "compiled" simulated artifact: its name plus the meta it executes
/// against (n_params for init, Adam hyperparameters for the optimizer).
pub struct Compiled {
    meta: Arc<ArtifactMeta>,
}

const KNOWN: &[&str] = &["init_params", "worker_step", "eval_loss", "ps_adam"];

pub fn compile_all(
    meta: &Arc<ArtifactMeta>,
    names: &[String],
) -> Result<HashMap<String, Compiled>> {
    let mut exes = HashMap::new();
    for name in names {
        let path = meta
            .hlo_path(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in meta.json"))?;
        // Mirror the real backend's stale-artifact loudness: the HLO file
        // must exist even though the simulator does not parse it.
        if !path.exists() {
            bail!("artifact file missing: {}", path.display());
        }
        if !KNOWN.contains(&name.as_str()) {
            bail!("sim backend has no semantics for artifact '{name}' (pjrt feature required)");
        }
        exes.insert(name.clone(), Compiled { meta: meta.clone() });
    }
    Ok(exes)
}

fn mean_sq(params: &[f32]) -> f32 {
    if params.is_empty() {
        return 0.0;
    }
    let s: f64 = params.iter().map(|p| (*p as f64) * (*p as f64)).sum();
    (s / params.len() as f64) as f32
}

/// Deterministic per-batch perturbation in [0, 0.01): makes successive
/// steps' losses wiggle like minibatch noise without hiding the trend.
fn batch_jitter(batch: &[i32]) -> f32 {
    let mut h: u64 = 0x9E37_79B9;
    for t in batch {
        h = h.wrapping_mul(0x100_0000_01B3).wrapping_add(*t as u32 as u64);
    }
    (h % 1000) as f32 * 1e-5
}

fn loss_of(params: &[f32]) -> f32 {
    0.5 + mean_sq(params)
}

/// Any one-element tensor as u64 (`Tensor::scalar` is f32-only).
fn scalar_u64(t: &Tensor) -> Option<u64> {
    match t {
        Tensor::U32 { data, .. } if data.len() == 1 => Some(data[0] as u64),
        Tensor::I32 { data, .. } if data.len() == 1 => Some(data[0] as u64),
        Tensor::F32 { data, .. } if data.len() == 1 => Some(data[0] as u64),
        _ => None,
    }
}

pub fn execute(exe: &Compiled, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
    match name {
        "init_params" => {
            let seed = inputs
                .first()
                .and_then(scalar_u64)
                .ok_or_else(|| anyhow!("init_params: seed must be a scalar"))?;
            let n = exe.meta.n_params;
            let mut rng = SplitMix64::new(seed ^ 0x746F_6E79); // "tony"
            let params: Vec<f32> = (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect();
            Ok(vec![Tensor::f32(&[n], params)])
        }
        "worker_step" => {
            let mut it = inputs.into_iter();
            let params = it
                .next()
                .and_then(|t| t.into_f32())
                .ok_or_else(|| anyhow!("worker_step: params must be f32"))?;
            let batch = it.next().ok_or_else(|| anyhow!("worker_step: missing batch"))?;
            let batch = batch
                .as_i32()
                .ok_or_else(|| anyhow!("worker_step: batch must be i32"))?;
            let loss = loss_of(&params) + batch_jitter(batch);
            let n = params.len();
            // grads = d/dp [½‖p‖²] = p: descending it reduces the loss.
            Ok(vec![Tensor::scalar_f32(loss), Tensor::f32(&[n], params)])
        }
        "eval_loss" => {
            let params = inputs
                .first()
                .and_then(|t| t.as_f32())
                .ok_or_else(|| anyhow!("eval_loss: params must be f32"))?;
            Ok(vec![Tensor::scalar_f32(loss_of(params))])
        }
        "ps_adam" => {
            let mut it = inputs.into_iter();
            let mut take = |what: &str| -> Result<Vec<f32>> {
                it.next()
                    .and_then(|t| t.into_f32())
                    .ok_or_else(|| anyhow!("ps_adam: {what} must be f32"))
            };
            let mut p = take("params")?;
            let g = take("grads")?;
            let mut m = take("m")?;
            let mut v = take("v")?;
            let step = it
                .next()
                .and_then(|t| t.scalar())
                .ok_or_else(|| anyhow!("ps_adam: step must be a scalar"))?;
            let lr = it
                .next()
                .and_then(|t| t.scalar())
                .ok_or_else(|| anyhow!("ps_adam: lr must be a scalar"))?;
            if g.len() != p.len() || m.len() != p.len() || v.len() != p.len() {
                bail!(
                    "ps_adam: length mismatch (p={}, g={}, m={}, v={})",
                    p.len(),
                    g.len(),
                    m.len(),
                    v.len()
                );
            }
            let hy = &exe.meta.adam;
            let (b1, b2, eps) = (hy.beta1, hy.beta2, hy.eps);
            let t = (step as f64).max(1.0);
            let bc1 = 1.0 - b1.powf(t);
            let bc2 = 1.0 - b2.powf(t);
            for i in 0..p.len() {
                let gi = g[i] as f64;
                let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
                let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
                m[i] = mi as f32;
                v[i] = vi as f32;
                let mhat = mi / bc1;
                let vhat = vi / bc2;
                p[i] = (p[i] as f64 - lr as f64 * mhat / (vhat.sqrt() + eps)) as f32;
            }
            let n = p.len();
            Ok(vec![Tensor::f32(&[n], p), Tensor::f32(&[n], m), Tensor::f32(&[n], v)])
        }
        other => bail!("sim backend has no semantics for artifact '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synthetic::SyntheticPreset;

    fn sim_exe() -> Compiled {
        let dir = std::env::temp_dir().join(format!(
            "tony-sim-test-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        SyntheticPreset::tiny().write(&dir).unwrap();
        let meta = Arc::new(ArtifactMeta::load(&dir).unwrap());
        std::fs::remove_dir_all(&dir).ok();
        Compiled { meta }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let exe = sim_exe();
        let a = execute(&exe, "init_params", vec![Tensor::scalar_u32(7)]).unwrap();
        let b = execute(&exe, "init_params", vec![Tensor::scalar_u32(7)]).unwrap();
        let c = execute(&exe, "init_params", vec![Tensor::scalar_u32(8)]).unwrap();
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
        assert_ne!(a[0].as_f32().unwrap(), c[0].as_f32().unwrap());
        assert_eq!(a[0].len(), exe.meta.n_params);
        assert!(a[0].as_f32().unwrap().iter().all(|p| p.abs() <= 1.0));
    }

    #[test]
    fn adam_descent_reduces_loss() {
        let exe = sim_exe();
        let n = exe.meta.n_params;
        let mut p = execute(&exe, "init_params", vec![Tensor::scalar_u32(1)])
            .unwrap()
            .remove(0)
            .into_f32()
            .unwrap();
        let mut m = vec![0f32; n];
        let mut v = vec![0f32; n];
        let batch: Vec<i32> = (0..10).collect();
        let first = loss_of(&p);
        for step in 1..=50u32 {
            let out = execute(
                &exe,
                "worker_step",
                vec![Tensor::f32(&[n], p.clone()), Tensor::i32(&[10], batch.clone())],
            )
            .unwrap();
            let loss = out[0].scalar().unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            let grads = out[1].as_f32().unwrap().to_vec();
            let upd = execute(
                &exe,
                "ps_adam",
                vec![
                    Tensor::f32(&[n], p),
                    Tensor::f32(&[n], grads),
                    Tensor::f32(&[n], m),
                    Tensor::f32(&[n], v),
                    Tensor::scalar_f32(step as f32),
                    Tensor::scalar_f32(0.01),
                ],
            )
            .unwrap();
            let mut it = upd.into_iter();
            p = it.next().unwrap().into_f32().unwrap();
            m = it.next().unwrap().into_f32().unwrap();
            v = it.next().unwrap().into_f32().unwrap();
        }
        let last = loss_of(&p);
        assert!(
            last < first,
            "50 Adam steps should reduce the loss ({first} -> {last})"
        );
    }

    #[test]
    fn unknown_artifact_rejected() {
        let exe = sim_exe();
        assert!(execute(&exe, "mystery_kernel", vec![]).is_err());
    }
}
