//! Runtime: load AOT artifacts (HLO text) and execute them.
//!
//! See DESIGN.md §2.  With the `pjrt` feature the flow mirrors
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`,
//! wrapped in a thread-owning [`Engine`] so the non-`Send` xla types
//! never cross threads.  The default (offline) build swaps in the
//! deterministic simulation backend in [`sim`], fed by generated presets
//! from [`synthetic`].

pub mod engine;
pub mod meta;
pub mod sim;
pub mod synthetic;
pub mod tensor;

pub use engine::{Engine, EngineHandle};
pub use meta::{AdamHyper, ArtifactMeta, ModelDims, Signature};
pub use tensor::Tensor;
