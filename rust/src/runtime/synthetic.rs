//! Synthetic artifact presets: a generated `meta.json` + placeholder HLO
//! files that the simulation backend (`runtime::sim`) can "compile" and
//! execute.
//!
//! The real artifacts are produced by `python/compile/aot.py` ("make
//! artifacts"), which needs JAX — unavailable in offline builds.  Tests,
//! benches, and `tony serve`/`tony demo` fall back to a synthetic preset
//! so the full client → RM → AM → executor → PS/worker path still runs
//! end-to-end.  Under the `pjrt` feature the placeholders are NOT valid
//! HLO, so [`ensure_preset`] refuses to fabricate them and real artifacts
//! must be supplied instead.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json::Json;

/// Dimensions for a generated preset (kept deliberately small: gateway
/// benches run dozens of these jobs concurrently).
#[derive(Debug, Clone)]
pub struct SyntheticPreset {
    pub preset: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_params: usize,
    pub chunk_len: usize,
}

fn sig_entry(dtype: &str, shape: &[usize]) -> Json {
    Json::Arr(vec![
        Json::Str(dtype.to_string()),
        Json::Arr(shape.iter().map(|d| Json::Num(*d as f64)).collect()),
    ])
}

fn sig(inputs: Vec<Json>, outputs: Vec<Json>) -> Json {
    let mut s = Json::obj();
    s.set("in", Json::Arr(inputs));
    s.set("out", Json::Arr(outputs));
    s
}

impl SyntheticPreset {
    /// The default preset: ~4k parameters in 2 PS chunks, 2×16-token
    /// batches — a job completes in well under a second of simulated
    /// training per step.
    pub fn tiny() -> SyntheticPreset {
        SyntheticPreset {
            preset: "synthetic-tiny".to_string(),
            vocab: 256,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
            batch: 2,
            n_params: 4096,
            chunk_len: 2048,
        }
    }

    fn meta_json(&self) -> Json {
        let mut model = Json::obj();
        model.set("vocab", self.vocab);
        model.set("d_model", self.d_model);
        model.set("n_heads", self.n_heads);
        model.set("n_layers", self.n_layers);
        model.set("d_ff", self.d_ff);
        model.set("seq_len", self.seq_len);
        model.set("batch", self.batch);

        let mut adam = Json::obj();
        adam.set("beta1", 0.9);
        adam.set("beta2", 0.999);
        adam.set("eps", 1e-8);

        let mut artifacts = Json::obj();
        for name in ["init_params", "worker_step", "eval_loss", "ps_adam"] {
            artifacts.set(name, format!("{name}.hlo.txt"));
        }

        let n = self.n_params;
        let c = self.chunk_len;
        let batch_shape = [self.batch, self.seq_len + 1];
        let mut signatures = Json::obj();
        signatures.set(
            "init_params",
            sig(vec![sig_entry("u32", &[])], vec![sig_entry("f32", &[n])]),
        );
        signatures.set(
            "worker_step",
            sig(
                vec![sig_entry("f32", &[n]), sig_entry("i32", &batch_shape)],
                vec![sig_entry("f32", &[]), sig_entry("f32", &[n])],
            ),
        );
        signatures.set(
            "eval_loss",
            sig(
                vec![sig_entry("f32", &[n]), sig_entry("i32", &batch_shape)],
                vec![sig_entry("f32", &[])],
            ),
        );
        signatures.set(
            "ps_adam",
            sig(
                vec![
                    sig_entry("f32", &[c]),
                    sig_entry("f32", &[c]),
                    sig_entry("f32", &[c]),
                    sig_entry("f32", &[c]),
                    sig_entry("f32", &[]),
                    sig_entry("f32", &[]),
                ],
                vec![sig_entry("f32", &[c]), sig_entry("f32", &[c]), sig_entry("f32", &[c])],
            ),
        );

        let mut j = Json::obj();
        j.set("preset", self.preset.as_str());
        j.set("model", model);
        j.set("n_params", self.n_params);
        j.set("chunk_len", self.chunk_len);
        j.set("adam", adam);
        j.set("artifacts", artifacts);
        j.set("signatures", signatures);
        j
    }

    /// Write the preset into `dir` (created if needed), overwriting any
    /// previous synthetic preset there.
    pub fn write(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating preset dir {}", dir.display()))?;
        for name in ["init_params", "worker_step", "eval_loss", "ps_adam"] {
            std::fs::write(
                dir.join(format!("{name}.hlo.txt")),
                format!(
                    "// synthetic placeholder for artifact '{name}' \
                     (executed by tony's runtime::sim backend, not PJRT)\n"
                ),
            )?;
        }
        std::fs::write(dir.join("meta.json"), self.meta_json().render_pretty())?;
        Ok(())
    }
}

/// True when this build executes artifacts with the simulation backend
/// (i.e. synthetic placeholder presets are runnable).
pub fn sim_backend_active() -> bool {
    !cfg!(feature = "pjrt")
}

/// Make sure `dir` holds a runnable preset: keep real artifacts if
/// present, otherwise generate the synthetic tiny preset (sim builds
/// only — with `pjrt` enabled placeholders would fail to compile, so
/// missing artifacts stay a hard error).
pub fn ensure_preset(dir: &Path) -> Result<()> {
    if dir.join("meta.json").exists() {
        return Ok(());
    }
    if !sim_backend_active() {
        bail!(
            "artifacts missing at {} and this is a pjrt build; run `make artifacts`",
            dir.display()
        );
    }
    SyntheticPreset::tiny().write(dir)
}

/// A process-scoped synthetic preset directory (generated on first use).
/// Separate processes get separate directories, so concurrently running
/// test binaries never race on the files.
pub fn default_dir() -> Result<PathBuf> {
    let dir = std::env::temp_dir().join(format!("tony-synthetic-{}", std::process::id()));
    ensure_preset(&dir)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactMeta;

    #[test]
    fn written_preset_round_trips_through_meta() {
        let dir = std::env::temp_dir().join(format!(
            "tony-synth-test-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let p = SyntheticPreset::tiny();
        p.write(&dir).unwrap();
        let meta = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(meta.preset, "synthetic-tiny");
        assert_eq!(meta.n_params, p.n_params);
        assert_eq!(meta.n_chunks(), 2);
        let ws = meta.signature("worker_step").unwrap();
        assert_eq!(ws.inputs[0].1, vec![p.n_params]);
        assert_eq!(ws.inputs[1].1, vec![p.batch, p.seq_len + 1]);
        for (_, file) in &meta.artifacts {
            assert!(dir.join(file).exists());
        }
        // Idempotent: ensure_preset keeps an existing preset.
        ensure_preset(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
