//! Host-side tensors passed between coordinator threads and the PJRT
//! engine thread.  `xla::Literal` wraps C++ pointers and is not `Send`,
//! so everything that crosses a thread boundary is one of these plain
//! buffers; conversion to/from literals happens on the engine thread only.

use crate::net::wire::{Reader, Wire, WireError, Writer};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_u32(v: u32) -> Tensor {
        Tensor::U32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::U32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
            Tensor::U32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_str(&self) -> &'static str {
        match self {
            Tensor::F32 { .. } => "f32",
            Tensor::I32 { .. } => "i32",
            Tensor::U32 { .. } => "u32",
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn into_f32(self) -> Option<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Scalar f32 value (accepts rank-0 or single-element tensors).
    pub fn scalar(&self) -> Option<f32> {
        match self {
            Tensor::F32 { data, .. } if data.len() == 1 => Some(data[0]),
            _ => None,
        }
    }
}

impl Wire for Tensor {
    fn encode(&self, w: &mut Writer) {
        let shape = self.shape();
        w.u32(shape.len() as u32);
        for s in shape {
            w.u64(*s as u64);
        }
        match self {
            Tensor::F32 { data, .. } => {
                w.u8(0);
                w.f32_slice(data);
            }
            Tensor::I32 { data, .. } => {
                w.u8(1);
                w.i32_slice(data);
            }
            Tensor::U32 { data, .. } => {
                w.u8(2);
                w.u32(data.len() as u32);
                for v in data {
                    w.u32(*v);
                }
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let rank = r.u32()? as usize;
        if rank > 16 {
            return Err(WireError(format!("absurd tensor rank {rank}")));
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u64()? as usize);
        }
        let expect: usize = shape.iter().product();
        let t = match r.u8()? {
            0 => Tensor::F32 { shape, data: r.f32_vec()? },
            1 => Tensor::I32 { shape, data: r.i32_vec()? },
            2 => {
                let n = r.u32()? as usize;
                let mut data = Vec::with_capacity(n.min(1 << 24));
                for _ in 0..n {
                    data.push(r.u32()?);
                }
                Tensor::U32 { shape, data }
            }
            d => return Err(WireError(format!("bad dtype tag {d}"))),
        };
        if t.len() != expect {
            return Err(WireError(format!(
                "tensor shape {:?} expects {} elements, got {}",
                t.shape(),
                expect,
                t.len()
            )));
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::f32(&[2, 3], vec![1.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype_str(), "f32");
        assert!(t.as_f32().is_some());
        assert!(t.as_i32().is_none());
        assert_eq!(Tensor::scalar_f32(2.5).scalar(), Some(2.5));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Tensor::f32(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn wire_round_trip() {
        for t in [
            Tensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]),
            Tensor::i32(&[3], vec![-1, 0, 7]),
            Tensor::U32 { shape: vec![], data: vec![42] },
            Tensor::zeros_f32(&[0]),
        ] {
            let b = t.to_bytes();
            assert_eq!(Tensor::from_bytes(&b).unwrap(), t);
        }
    }

    #[test]
    fn wire_rejects_shape_mismatch() {
        let t = Tensor::f32(&[4], vec![0.0; 4]);
        let mut b = t.to_bytes();
        // Corrupt the rank-1 dim from 4 to 5.
        b[4] = 5;
        assert!(Tensor::from_bytes(&b).is_err());
    }
}
