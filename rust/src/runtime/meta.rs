//! `artifacts/<preset>/meta.json` — the contract between the AOT pipeline
//! (python/compile/aot.py) and the Rust runtime.  Shapes and dtypes are
//! asserted at engine start so a stale artifact directory fails loudly
//! instead of feeding garbage into training.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct AdamHyper {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

/// One artifact's IO signature: ordered (dtype, shape) pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct Signature {
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub preset: String,
    pub dims: ModelDims,
    pub n_params: usize,
    pub chunk_len: usize,
    pub adam: AdamHyper,
    /// Artifact name -> HLO file name (relative to the preset dir).
    pub artifacts: Vec<(String, String)>,
    pub signatures: Vec<(String, Signature)>,
    pub dir: PathBuf,
}

fn parse_sig_list(j: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    let arr = j.as_arr().ok_or_else(|| anyhow!("signature list must be array"))?;
    let mut out = Vec::new();
    for ent in arr {
        let pair = ent.as_arr().ok_or_else(|| anyhow!("signature entry must be [dtype, shape]"))?;
        if pair.len() != 2 {
            bail!("signature entry must have 2 elements");
        }
        let dtype = pair[0].as_str().ok_or_else(|| anyhow!("dtype must be string"))?.to_string();
        let shape = pair[1]
            .as_arr()
            .ok_or_else(|| anyhow!("shape must be array"))?
            .iter()
            .map(|d| d.as_u64().map(|v| v as usize).ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        out.push((dtype, shape));
    }
    Ok(out)
}

impl ArtifactMeta {
    pub fn load(preset_dir: &Path) -> Result<ArtifactMeta> {
        let meta_path = preset_dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", meta_path.display()))?;

        let model = j.get("model").ok_or_else(|| anyhow!("meta.json missing 'model'"))?;
        let dim = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(|v| v.as_u64())
                .map(|v| v as usize)
                .ok_or_else(|| anyhow!("meta.json model.{k} missing"))
        };
        let dims = ModelDims {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_heads: dim("n_heads")?,
            n_layers: dim("n_layers")?,
            d_ff: dim("d_ff")?,
            seq_len: dim("seq_len")?,
            batch: dim("batch")?,
        };
        let adam = j.get("adam").ok_or_else(|| anyhow!("meta.json missing 'adam'"))?;
        let adam = AdamHyper {
            beta1: adam.get("beta1").and_then(|v| v.as_f64()).unwrap_or(0.9),
            beta2: adam.get("beta2").and_then(|v| v.as_f64()).unwrap_or(0.999),
            eps: adam.get("eps").and_then(|v| v.as_f64()).unwrap_or(1e-8),
        };
        let mut artifacts = Vec::new();
        for (name, file) in j
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .ok_or_else(|| anyhow!("meta.json missing 'artifacts'"))?
        {
            artifacts.push((
                name.clone(),
                file.as_str().ok_or_else(|| anyhow!("artifact path must be string"))?.to_string(),
            ));
        }
        let mut signatures = Vec::new();
        if let Some(sigs) = j.get("signatures").and_then(|s| s.as_obj()) {
            for (name, sig) in sigs {
                signatures.push((
                    name.clone(),
                    Signature {
                        inputs: parse_sig_list(
                            sig.get("in").ok_or_else(|| anyhow!("sig missing 'in'"))?,
                        )?,
                        outputs: parse_sig_list(
                            sig.get("out").ok_or_else(|| anyhow!("sig missing 'out'"))?,
                        )?,
                    },
                ));
            }
        }
        Ok(ArtifactMeta {
            preset: j
                .get("preset")
                .and_then(|p| p.as_str())
                .unwrap_or("unknown")
                .to_string(),
            dims,
            n_params: j
                .get("n_params")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("meta.json missing n_params"))? as usize,
            chunk_len: j
                .get("chunk_len")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("meta.json missing chunk_len"))? as usize,
            adam,
            artifacts,
            signatures,
            dir: preset_dir.to_path_buf(),
        })
    }

    pub fn signature(&self, name: &str) -> Option<&Signature> {
        self.signatures.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }

    pub fn hlo_path(&self, name: &str) -> Option<PathBuf> {
        self.artifacts
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, f)| self.dir.join(f))
    }

    /// Number of padded chunks a flat vector of `n_params` splits into.
    pub fn n_chunks(&self) -> usize {
        self.n_params.div_ceil(self.chunk_len)
    }

    /// Tokens-per-step for throughput accounting (batch * seq predictions).
    pub fn tokens_per_step(&self) -> usize {
        self.dims.batch * self.dims.seq_len
    }

    /// Approximate FLOPs per training step (fwd+bwd ~ 6 * params * tokens,
    /// the standard transformer estimate) — used by Dr. Elephant heuristics
    /// and the §Perf roofline table.
    pub fn flops_per_step(&self) -> f64 {
        6.0 * self.n_params as f64 * self.tokens_per_step() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta_json() -> String {
        r#"{
          "preset": "tiny",
          "model": {"vocab": 256, "d_model": 64, "n_heads": 4, "n_layers": 2,
                    "d_ff": 256, "seq_len": 64, "batch": 4,
                    "block_q": 64, "block_k": 64},
          "n_params": 120064,
          "chunk_len": 65536,
          "adam": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-8},
          "artifacts": {"worker_step": "worker_step.hlo.txt",
                        "ps_adam": "ps_adam.hlo.txt"},
          "signatures": {
            "worker_step": {"in": [["f32", [120064]], ["i32", [4, 65]]],
                            "out": [["f32", []], ["f32", [120064]]]}
          }
        }"#
        .to_string()
    }

    #[test]
    fn load_meta() {
        let dir = std::env::temp_dir().join(format!("tony-meta-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), sample_meta_json()).unwrap();
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.dims.d_model, 64);
        assert_eq!(m.n_params, 120064);
        assert_eq!(m.n_chunks(), 2);
        assert_eq!(m.tokens_per_step(), 256);
        let sig = m.signature("worker_step").unwrap();
        assert_eq!(sig.inputs[1].1, vec![4, 65]);
        assert_eq!(m.hlo_path("ps_adam").unwrap(), dir.join("ps_adam.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_fields_error() {
        let dir = std::env::temp_dir().join(format!("tony-meta-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), "{}").unwrap();
        assert!(ArtifactMeta::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
