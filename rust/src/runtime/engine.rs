//! The execution engine: loads `artifacts/<preset>/*.hlo.txt` and
//! executes them on behalf of the rest of the system.
//!
//! Two backends, selected at compile time:
//!
//! - **`pjrt` feature**: the real thing — HLO text is compiled on a CPU
//!   PJRT client via the `xla` crate.  `xla`'s types wrap raw C++
//!   pointers and are not `Send`, so the client and every compiled
//!   executable live on ONE dedicated engine thread; the rest of the
//!   system talks to it through a cloneable, thread-safe
//!   [`EngineHandle`] carrying plain [`Tensor`] buffers over channels.
//! - **default (no `pjrt`)**: the deterministic simulation backend in
//!   [`super::sim`] — same artifact names, same signatures, same engine
//!   thread discipline, but the "kernels" are closed-form host math.
//!   This is what lets the orchestration stack (client/AM/executor/
//!   gateway) run end-to-end in offline builds and CI where the `xla`
//!   crate cannot be fetched.
//!
//! Either way the threading shape is faithful to the paper's deployment:
//! each task container runs its own runtime instance (here: its own
//! engine thread).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::meta::{ArtifactMeta, Signature};
use super::tensor::Tensor;

#[cfg(feature = "pjrt")]
use self::pjrt_backend as backend;
#[cfg(not(feature = "pjrt"))]
use super::sim as backend;

enum Cmd {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: mpsc::SyncSender<Result<(Vec<Tensor>, f64)>>,
    },
    Shutdown,
}

/// Cloneable handle to a running engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Cmd>,
    meta: Arc<ArtifactMeta>,
}

/// Owns the engine thread; dropping it shuts the thread down.
pub struct Engine {
    handle: EngineHandle,
    thread: Option<std::thread::JoinHandle<()>>,
}

fn check_inputs(sig: &Signature, inputs: &[Tensor]) -> Result<()> {
    if sig.inputs.len() != inputs.len() {
        bail!("expected {} inputs, got {}", sig.inputs.len(), inputs.len());
    }
    for (i, ((dtype, shape), t)) in sig.inputs.iter().zip(inputs).enumerate() {
        if t.dtype_str() != dtype {
            bail!("input {i}: expected dtype {dtype}, got {}", t.dtype_str());
        }
        if t.shape() != shape.as_slice() {
            bail!("input {i}: expected shape {:?}, got {:?}", shape, t.shape());
        }
    }
    Ok(())
}

/// The real PJRT backend (needs the unvendorable `xla` crate; see the
/// `pjrt` feature notes in Cargo.toml).
#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::Arc;

    use anyhow::{anyhow, bail, Result};

    use super::super::meta::ArtifactMeta;
    use super::super::tensor::Tensor;

    pub struct Compiled {
        exe: xla::PjRtLoadedExecutable,
    }

    fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
        let lit = match t {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
            Tensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims)
            .map_err(|e| anyhow!("reshape {:?} failed: {e}", t.shape()))
    }

    fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        let t = match shape.ty() {
            xla::ElementType::F32 => Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?,
            },
            xla::ElementType::S32 => Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?,
            },
            xla::ElementType::U32 => Tensor::U32 {
                shape: dims,
                data: lit.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e}"))?,
            },
            other => bail!("unsupported output element type {other:?}"),
        };
        Ok(t)
    }

    pub fn compile_all(
        meta: &Arc<ArtifactMeta>,
        names: &[String],
    ) -> Result<HashMap<String, Compiled>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        let mut exes = HashMap::new();
        for name in names {
            let path: PathBuf = meta
                .hlo_path(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in meta.json"))?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            exes.insert(name.clone(), Compiled { exe });
        }
        Ok(exes)
    }

    pub fn execute(exe: &Compiled, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let bufs = exe
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let out_lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e}"))?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

fn engine_main(
    meta: Arc<ArtifactMeta>,
    artifacts: Vec<String>,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    // Compile phase: failures are reported through `ready`.
    let exes = match backend::compile_all(&meta, &artifacts) {
        Ok(exes) => {
            let _ = ready.send(Ok(()));
            exes
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Shutdown => break,
            Cmd::Execute { name, inputs, reply } => {
                let result = (|| -> Result<(Vec<Tensor>, f64)> {
                    let exe = exes
                        .get(&name)
                        .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
                    if let Some(sig) = meta.signature(&name) {
                        check_inputs(sig, &inputs)?;
                    }
                    let start = Instant::now();
                    let outs = backend::execute(exe, &name, inputs)?;
                    let exec_ms = start.elapsed().as_secs_f64() * 1e3;
                    Ok((outs, exec_ms))
                })();
                let _ = reply.send(result);
            }
        }
    }
}

impl Engine {
    /// Load + compile the named artifacts from a preset dir and start the
    /// engine thread.  `artifacts = None` compiles everything in meta.json.
    pub fn start(preset_dir: &std::path::Path, artifacts: Option<&[&str]>) -> Result<Engine> {
        let meta = Arc::new(ArtifactMeta::load(preset_dir)?);
        Self::start_with_meta(meta, artifacts)
    }

    pub fn start_with_meta(meta: Arc<ArtifactMeta>, artifacts: Option<&[&str]>) -> Result<Engine> {
        let names: Vec<String> = match artifacts {
            Some(list) => list.iter().map(|s| s.to_string()).collect(),
            None => meta.artifacts.iter().map(|(n, _)| n.clone()).collect(),
        };
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::sync_channel(1);
        let meta2 = meta.clone();
        let thread = std::thread::Builder::new()
            .name(format!("engine-{}", meta.preset))
            .spawn(move || engine_main(meta2, names, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during setup")??;
        Ok(Engine { handle: EngineHandle { tx, meta }, thread: Some(thread) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Cmd::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl EngineHandle {
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute one artifact; returns outputs and device execution time.
    pub fn execute_timed(&self, name: &str, inputs: Vec<Tensor>) -> Result<(Vec<Tensor>, f64)> {
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        self.tx
            .send(Cmd::Execute { name: name.to_string(), inputs, reply: reply_tx })
            .map_err(|_| anyhow!("engine thread is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("engine thread dropped reply"))?
    }

    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        Ok(self.execute_timed(name, inputs)?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests against artifacts live in rust/tests/; these unit
    // tests cover the signature checker only (no backend needed).
    #[test]
    fn signature_mismatches_detected() {
        let sig = Signature {
            inputs: vec![("f32".into(), vec![4]), ("i32".into(), vec![2, 3])],
            outputs: vec![],
        };
        let ok = vec![Tensor::zeros_f32(&[4]), Tensor::i32(&[2, 3], vec![0; 6])];
        assert!(check_inputs(&sig, &ok).is_ok());
        let wrong_count = vec![Tensor::zeros_f32(&[4])];
        assert!(check_inputs(&sig, &wrong_count).is_err());
        let wrong_dtype = vec![Tensor::i32(&[4], vec![0; 4]), Tensor::i32(&[2, 3], vec![0; 6])];
        assert!(check_inputs(&sig, &wrong_dtype).is_err());
        let wrong_shape = vec![Tensor::zeros_f32(&[5]), Tensor::i32(&[2, 3], vec![0; 6])];
        assert!(check_inputs(&sig, &wrong_shape).is_err());
    }
}
