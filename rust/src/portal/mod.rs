//! The TonY portal: central monitoring UI (paper §1 "Lack of monitoring"
//! and §2.2's "users can directly access the visualization UI and task
//! logs from one place").
//!
//! A small HTTP/1.0 server (std TCP, thread-per-connection) serving:
//!
//! - `GET /`            — HTML overview (job phase, attempt, task table)
//! - `GET /status`      — the AM state snapshot as JSON
//! - `GET /cluster`     — RM node/queue utilization as JSON
//! - `GET /losses`      — the chief's loss curve as JSON
//! - `GET /metrics`     — Prometheus text format: per-task gauges, per-queue
//!   cluster utilization, and the job's `tony_stage_seconds` stage-latency
//!   histogram when tracing is on (see `docs/METRICS.md`)
//! - `GET /series`      — the job's ring-buffered time series as JSON
//! - `GET /findings`    — streaming Dr. Elephant verdicts for the *running* job
//! - `GET /logs/<task>` — captured log lines mentioning the task
//!
//! Unknown routes (and `/logs/<task>` for a task the job does not have)
//! return `404` with a JSON error body.  The portal URL is registered as
//! the app's tracking URL, so the client surfaces it exactly like YARN's
//! proxy would.
//!
//! # Example
//!
//! Render the Prometheus exposition without going over HTTP:
//!
//! ```
//! use std::sync::Arc;
//! use tony::am::AmState;
//! use tony::tonyconf::{JobConfBuilder, JobSpec};
//! use tony::yarn::{Resource, ResourceManager};
//!
//! let conf = JobConfBuilder::new("doc").instances("worker", 1).build();
//! let spec = JobSpec::from_conf(&conf).unwrap();
//! let state = Arc::new(AmState::new(&spec));
//! state.begin_attempt(1);
//! let rm = ResourceManager::start_uniform(1, Resource::new(1024, 2, 0));
//! let text = tony::portal::prometheus_text(&state, &rm);
//! assert!(text.contains("tony_queue_utilization"));
//! assert!(text.contains("tony_task_step"));
//! ```

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::am::AmState;
use crate::json::Json;
use crate::util::HostPort;
use crate::yarn::ResourceManager;

pub struct Portal {
    pub addr: HostPort,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Write a complete HTTP/1.0 response (shared by the portal and the
/// gateway API server).
pub fn http_response(stream: &mut std::net::TcpStream, status: &str, ctype: &str, body: &str) {
    let _ = write!(
        stream,
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Render the standard JSON error body every portal/gateway handler uses
/// for error statuses: `{"code": ..., "error": ...}`.
pub fn error_body(code: &str, message: &str) -> String {
    let mut j = Json::obj();
    j.set("code", code);
    j.set("error", message);
    j.render_pretty()
}

/// Respond `404 Not Found` with the standard JSON error body — unknown
/// routes and unknown resources answer identically everywhere.
pub fn respond_not_found(stream: &mut std::net::TcpStream, message: &str) {
    http_response(
        stream,
        "404 Not Found",
        "application/json",
        &error_body("not-found", message),
    );
}

/// Prometheus text format content type (exposition format 0.0.4).
pub const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// The AM portal's `GET /metrics` body: per-task gauges from the latest
/// heartbeat snapshot, per-queue scheduler gauges, and — when the job is
/// traced — the `tony_stage_seconds` histogram over the job's own stage
/// breakdown so far (open stages count up to now).
pub fn prometheus_text(state: &AmState, rm: &ResourceManager) -> String {
    let mut prom = crate::metrics::PromText::new();
    let rows = crate::metrics::task_rows(state.task_metrics(), &[]);
    crate::metrics::render_task_metrics(&mut prom, &rows);
    crate::metrics::render_cluster_metrics(&mut prom, rm);
    if let Some(trace) = state.trace() {
        let mut stages = std::collections::BTreeMap::new();
        for (stage, ms) in trace.stage_millis() {
            stages
                .entry(stage.as_str())
                .or_insert_with(crate::metrics::Histogram::stage_seconds)
                .observe(ms as f64 / 1000.0);
        }
        if !stages.is_empty() {
            crate::metrics::render_stage_histograms(&mut prom, &stages);
        }
    }
    prom.finish()
}

/// The portal's `GET /findings` body: the streaming Dr. Elephant
/// verdicts for the running job, plus the phase they were computed in.
fn findings_json(state: &AmState) -> Json {
    let findings = crate::drelephant::analyze_live(state);
    let mut j = Json::obj();
    j.set("phase", format!("{:?}", state.phase()));
    j.set("findings", crate::drelephant::findings_json(&findings));
    j
}

/// A parsed incoming HTTP request: method, path, and (for POSTs) the
/// body as declared by Content-Length.
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read and parse one HTTP request from a freshly accepted connection.
/// Headers are capped at 64 KiB; bodies over 1 MiB are rejected with an
/// error (the gateway API maps it to 413).  Reads use a 5 s timeout so a
/// stalled client cannot hold a handler thread indefinitely.
pub fn read_http_request(stream: &mut std::net::TcpStream) -> std::io::Result<HttpRequest> {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 2048];
    let header_end = loop {
        if let Some(i) = find_subslice(&buf, b"\r\n\r\n") {
            break Some(i);
        }
        if buf.len() > 64 * 1024 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request headers too large",
            ));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            break None; // connection closed before a blank line
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let (head, rest): (&[u8], &[u8]) = match header_end {
        Some(i) => (&buf[..i], &buf[i + 4..]),
        None => (&buf[..], &[]),
    };
    let head = String::from_utf8_lossy(head).into_owned();
    let mut lines = head.lines();
    let req_line = lines.next().unwrap_or("");
    let mut parts = req_line.split_whitespace();
    let method = parts.next().unwrap_or("GET").to_ascii_uppercase();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_len = 0usize;
    for l in lines {
        if let Some((k, v)) = l.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_len > 1 << 20 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "request body exceeds 1 MiB",
        ));
    }
    let mut body = rest.to_vec();
    while body.len() < content_len {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            break;
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_len);
    Ok(HttpRequest { method, path, body: String::from_utf8_lossy(&body).into_owned() })
}

fn render_html(state: &AmState) -> String {
    let snap = state.snapshot_json();
    let phase = snap.get("phase").and_then(|p| p.as_str()).unwrap_or("?").to_string();
    let attempt = snap.get("attempt").and_then(|a| a.as_u64()).unwrap_or(0);
    let mut rows = String::new();
    if let Some(tasks) = snap.get("tasks").and_then(|t| t.as_arr()) {
        for t in tasks {
            let get = |k: &str| -> String {
                t.get(k)
                    .map(|v| match v {
                        Json::Str(s) => s.clone(),
                        Json::Null => "-".to_string(),
                        other => other.render(),
                    })
                    .unwrap_or_else(|| "-".to_string())
            };
            rows.push_str(&format!(
                "<tr><td>{}</td><td>{}</td><td>{}</td><td>{}</td><td>{}</td>\
                 <td>{}</td><td><a href=\"{}\">logs</a></td></tr>\n",
                get("task"),
                get("container"),
                get("endpoint"),
                get("step"),
                get("loss"),
                get("exit"),
                get("log_url"),
            ));
        }
    }
    format!(
        "<!doctype html><html><head><title>TonY portal</title></head><body>\
         <h1>TonY job</h1><p>phase: <b>{phase}</b> | attempt: {attempt}</p>\
         <table border=1 cellpadding=4><tr><th>task</th><th>container</th>\
         <th>endpoint</th><th>step</th><th>loss</th><th>exit</th><th>logs</th></tr>\
         {rows}</table>\
         <p><a href=\"/status\">status.json</a> | <a href=\"/cluster\">cluster.json</a> \
         | <a href=\"/losses\">losses.json</a></p></body></html>"
    )
}

/// RM node/queue utilization as JSON (shared with the gateway API).
pub fn cluster_json(rm: &ResourceManager) -> Json {
    let mut nodes = Vec::new();
    for (id, free, cap) in rm.node_usage() {
        let mut n = Json::obj();
        n.set("node", id.to_string());
        n.set("free_mb", free.memory_mb);
        n.set("cap_mb", cap.memory_mb);
        n.set("free_vcores", free.vcores as u64);
        n.set("free_gpus", free.gpus as u64);
        nodes.push(n);
    }
    let mut queues = Vec::new();
    for (name, used) in rm.queue_usage() {
        let mut q = Json::obj();
        q.set("queue", name);
        q.set("used_mb", used.memory_mb);
        queues.push(q);
    }
    let mut j = Json::obj();
    j.set("nodes", Json::Arr(nodes));
    j.set("queues", Json::Arr(queues));
    j.set("alive_nodes", rm.alive_node_count());
    j
}

fn losses_json(state: &AmState) -> Json {
    let mut j = Json::obj();
    match state.chief_metrics() {
        Some(m) => {
            j.set("step", m.step);
            j.set("loss", m.loss as f64);
            j.set("eval_loss", m.eval_loss as f64);
            j.set(
                "history",
                Json::Arr(
                    m.loss_history
                        .iter()
                        .map(|(s, l)| {
                            let mut e = Json::obj();
                            e.set("step", *s).set("loss", *l as f64);
                            e
                        })
                        .collect(),
                ),
            );
        }
        None => {
            j.set("history", Json::Arr(vec![]));
        }
    }
    j
}

impl Portal {
    pub fn start(state: Arc<AmState>, rm: Arc<ResourceManager>) -> Result<Portal> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = HostPort::from_addr(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new().name("portal".into()).spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        let Ok(req) = read_http_request(&mut stream) else { continue };
                        let path = req.path;
                        match path.as_str() {
                            "/" => http_response(
                                &mut stream,
                                "200 OK",
                                "text/html",
                                &render_html(&state),
                            ),
                            "/status" => http_response(
                                &mut stream,
                                "200 OK",
                                "application/json",
                                &state.snapshot_json().render_pretty(),
                            ),
                            "/cluster" => http_response(
                                &mut stream,
                                "200 OK",
                                "application/json",
                                &cluster_json(&rm).render_pretty(),
                            ),
                            "/losses" => http_response(
                                &mut stream,
                                "200 OK",
                                "application/json",
                                &losses_json(&state).render_pretty(),
                            ),
                            "/metrics" => http_response(
                                &mut stream,
                                "200 OK",
                                PROM_CONTENT_TYPE,
                                &prometheus_text(&state, &rm),
                            ),
                            "/series" => http_response(
                                &mut stream,
                                "200 OK",
                                "application/json",
                                &state.metrics_registry().series_json().render_pretty(),
                            ),
                            "/findings" => http_response(
                                &mut stream,
                                "200 OK",
                                "application/json",
                                &findings_json(&state).render_pretty(),
                            ),
                            p if p.starts_with("/logs/") => {
                                let task = p.trim_start_matches("/logs/");
                                if !state.has_task(task) {
                                    respond_not_found(
                                        &mut stream,
                                        &format!("no such task '{task}'"),
                                    );
                                    continue;
                                }
                                let body = format!(
                                    "logs for {task}: interleaved in the daemon stderr \
                                     (TONY_LOG=debug); per-task capture via logging::capture_start"
                                );
                                http_response(&mut stream, "200 OK", "text/plain", &body);
                            }
                            _ => respond_not_found(&mut stream, "not found"),
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        crate::util::clock::real_sleep(Duration::from_millis(20));
                    }
                    Err(_) => break,
                }
            }
        })?;
        Ok(Portal { addr, stop, thread: Some(thread) })
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for Portal {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Blocking HTTP GET helper (tests + workflow health checks).
pub fn http_get(url: &str) -> Result<(u16, String)> {
    http_request("GET", url, "")
}

/// Blocking HTTP request helper: any method, optional body (sent as JSON
/// when non-empty).  Returns (status code, response body).
pub fn http_request(method: &str, url: &str, body: &str) -> Result<(u16, String)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| anyhow::anyhow!("only http:// URLs supported"))?;
    let (hostport, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    let mut stream = std::net::TcpStream::connect(hostport)?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    if body.is_empty() {
        write!(stream, "{method} {path} HTTP/1.0\r\nHost: {hostport}\r\n\r\n")?;
    } else {
        write!(
            stream,
            "{method} {path} HTTP/1.0\r\nHost: {hostport}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )?;
    }
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let status: u16 = resp
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = resp.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::{JobConfBuilder, JobSpec};
    use crate::yarn::Resource;

    #[test]
    fn portal_serves_all_routes() {
        let conf = JobConfBuilder::new("p").instances("worker", 1).build();
        let spec = JobSpec::from_conf(&conf).unwrap();
        let state = Arc::new(AmState::new(&spec));
        state.begin_attempt(1);
        let rm = ResourceManager::start_uniform(2, Resource::new(1024, 2, 0));
        let portal = Portal::start(state, rm).unwrap();

        let (code, body) = http_get(&portal.url()).unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("TonY job"));

        let (code, body) = http_get(&format!("{}/status", portal.url())).unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("attempt").unwrap().as_u64(), Some(1));

        let (code, body) = http_get(&format!("{}/cluster", portal.url())).unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("alive_nodes").unwrap().as_u64(), Some(2));

        let (code, body) = http_get(&format!("{}/losses", portal.url())).unwrap();
        assert_eq!(code, 200);
        assert!(Json::parse(&body).is_ok());

        let (code, _) = http_get(&format!("{}/logs/worker:0", portal.url())).unwrap();
        assert_eq!(code, 200);

        let (code, body) = http_get(&format!("{}/metrics", portal.url())).unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("# TYPE tony_task_step gauge"), "{body}");
        assert!(body.contains("tony_task_step{task=\"worker:0\"}"), "{body}");
        assert!(body.contains("tony_queue_utilization{queue=\"default\"}"), "{body}");

        let (code, body) = http_get(&format!("{}/series", portal.url())).unwrap();
        assert_eq!(code, 200);
        assert!(Json::parse(&body).unwrap().get("tasks").is_some());

        let (code, body) = http_get(&format!("{}/findings", portal.url())).unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        assert!(j.get("findings").and_then(|f| f.as_arr()).is_some());

        let (code, _) = http_get(&format!("{}/nope", portal.url())).unwrap();
        assert_eq!(code, 404);
    }

    /// The per-job portal scrape carries the job's own stage-latency
    /// histogram once a trace is attached — and no `tony_stage_seconds`
    /// family at all for untraced jobs.
    #[test]
    fn portal_metrics_include_stage_histogram_when_traced() {
        let conf = JobConfBuilder::new("traced").instances("worker", 1).build();
        let spec = JobSpec::from_conf(&conf).unwrap();
        let state = Arc::new(AmState::new(&spec));
        let store = crate::trace::SpanStore::new(
            &crate::trace::TraceConf::default(),
            crate::util::clock::SystemClock::shared(),
            7,
        );
        state.set_trace(&store);
        state.begin_attempt(1); // the trace hook opens the scheduling stage
        let rm = ResourceManager::start_uniform(1, Resource::new(1024, 2, 0));
        let text = prometheus_text(&state, &rm);
        assert!(text.contains("# TYPE tony_stage_seconds histogram"), "{text}");
        assert!(text.contains("tony_stage_seconds_bucket{stage=\"scheduling\""), "{text}");
        assert!(text.contains("tony_stage_seconds_count{stage=\"scheduling\"} 1"), "{text}");

        let bare = Arc::new(AmState::new(&spec));
        bare.begin_attempt(1);
        let text = prometheus_text(&bare, &rm);
        assert!(!text.contains("tony_stage_seconds"), "{text}");
    }

    #[test]
    fn unknown_routes_and_tasks_get_json_404s() {
        let conf = JobConfBuilder::new("p404").instances("worker", 1).build();
        let spec = JobSpec::from_conf(&conf).unwrap();
        let state = Arc::new(AmState::new(&spec));
        state.begin_attempt(1);
        let rm = ResourceManager::start_uniform(1, Resource::new(1024, 2, 0));
        let portal = Portal::start(state, rm).unwrap();

        for path in ["/nope", "/api/v1/anything", "/logs/worker:7"] {
            let (code, body) = http_get(&format!("{}{path}", portal.url())).unwrap();
            assert_eq!(code, 404, "{path}");
            let j = Json::parse(&body).unwrap_or_else(|e| panic!("{path}: not JSON ({e}): {body}"));
            assert_eq!(j.get("code").and_then(|c| c.as_str()), Some("not-found"), "{path}");
            assert!(j.get("error").is_some(), "{path}");
        }
    }

    #[test]
    fn streaming_straggler_verdict_visible_mid_run() {
        use crate::am::protocol::{HeartbeatMsg, AM_HEARTBEAT};
        use crate::am::state::AmRpcHandler;
        use crate::framework::TaskMetrics;
        use crate::net::rpc::RpcHandler;
        use crate::net::wire::Wire;

        let conf = JobConfBuilder::new("strag").instances("worker", 3).build();
        let spec = JobSpec::from_conf(&conf).unwrap();
        let state = Arc::new(AmState::new(&spec));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());
        // Two healthy workers and one 4x-slower straggler heartbeat in.
        for (idx, step_ms) in [(0u32, 10.0f64), (1, 11.0), (2, 44.0)] {
            let hb = HeartbeatMsg {
                task_type: "worker".into(),
                index: idx,
                spec_version: 1,
                metrics: TaskMetrics { step: 50, step_ms_avg: step_ms, ..Default::default() },
            };
            handler.handle(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
        }
        let rm = ResourceManager::start_uniform(1, Resource::new(1024, 2, 0));
        let portal = Portal::start(state.clone(), rm).unwrap();
        // The job is still mid-run (no task exited), yet the portal
        // already serves the straggler verdict.
        assert!(state.task_metrics().iter().all(|(_, m)| !m.finished));
        let (code, body) = http_get(&format!("{}/findings", portal.url())).unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        let findings = j.get("findings").and_then(|f| f.as_arr()).unwrap();
        let straggler = findings
            .iter()
            .find(|f| f.get("heuristic").and_then(|h| h.as_str()) == Some("straggler"))
            .expect("straggler flagged while running");
        assert_eq!(straggler.get("task").and_then(|t| t.as_str()), Some("worker:2"));
    }
}
