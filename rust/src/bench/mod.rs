//! Micro bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is `harness = false` and uses this:
//! warmup + timed iterations, median/mean/p95 reporting, and aligned
//! table printing so every bench regenerates its EXPERIMENTS.md table
//! verbatim.  `cargo bench` runs them all.

use std::time::{Duration, Instant};

pub mod cluster;

#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn p99_ms(&self) -> f64 {
        self.p99_ns / 1e6
    }

    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns.max(1e-9)
    }
}

/// Time `f` for up to `max_iters` iterations or `budget`, whichever first
/// (after `warmup` untimed runs).  Returns per-iteration stats.
pub fn bench<F: FnMut()>(warmup: usize, max_iters: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    stats_from(samples)
}

/// Like [`bench`], but `f` reports the duration to record itself.  This
/// is what lets a bench keep expensive per-iteration setup (rebuilding a
/// scheduler, regenerating asks) *outside* the measured window: do the
/// setup untimed inside `f`, wrap only the interesting call in an
/// `Instant`, and return that elapsed slice.  The iteration budget still
/// counts wall-clock (setup included) so runaway setup can't hang the
/// bench.
pub fn bench_sampled<F: FnMut() -> Duration>(
    warmup: usize,
    max_iters: usize,
    budget: Duration,
    mut f: F,
) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(max_iters.min(4096));
    let start = Instant::now();
    for _ in 0..max_iters {
        samples.push(f().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    stats_from(samples)
}

/// Build stats from raw per-iteration samples (ns).
pub fn stats_from(mut samples: Vec<f64>) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    Stats {
        iters: n,
        mean_ns: mean,
        median_ns: samples[n / 2],
        p95_ns: samples[(n as f64 * 0.95) as usize % n.max(1)],
        p99_ns: samples[(n as f64 * 0.99) as usize % n.max(1)],
        min_ns: samples[0],
    }
}

/// One measured wall-clock run (for end-to-end benches where iterating is
/// too expensive): returns elapsed ms.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64() * 1e3)
}

/// Aligned table printer.  Benches print their rows through this so the
/// output is diff-stable for EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n### {title}");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// `fmt!`-lite helpers for bench rows.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

pub fn n(v: impl std::fmt::Display) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let s = bench(2, 100, Duration::from_millis(200), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters > 10);
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns * 1.0001);
    }

    #[test]
    fn table_prints_aligned() {
        let mut t = Table::new(&["a", "long-header"]);
        t.row(&[n(1), f1(2.5)]);
        t.print("test"); // just must not panic
    }

    #[test]
    fn stats_from_percentiles() {
        let s = stats_from((1..=100).map(|v| v as f64).collect());
        assert_eq!(s.median_ns, 51.0);
        assert_eq!(s.min_ns, 1.0);
        assert!(s.p95_ns >= 95.0);
    }
}
