//! Discrete-event cluster + workload generator for the scheduler benches.
//!
//! `bench_scheduler` and `bench_contention` need realistic 10k-node /
//! 5k-job / 1k-queue scenarios to exercise the placement indexes at the
//! operating point the survey papers describe, and they need them
//! *deterministically* so indexed-vs-linear comparisons and CI smoke
//! bounds are reproducible.  Everything here is SplitMix64-seeded: the
//! same `ClusterSpec` always yields the same node mix, queue tree, job
//! arrivals, and release schedule.
//!
//! The runner is a discrete-event loop over "allocate rounds": each
//! round injects the jobs arriving at that tick as gangs, times one
//! `CapacityScheduler::schedule()` pass (setup and release bookkeeping
//! stay outside the measured window), then releases every container
//! whose job finished this tick through
//! `CapacityScheduler::release_container` — the same grant/release
//! index lifecycle the RM drives in production.

use std::time::{Duration, Instant};

use crate::util::ids::ApplicationId;
use crate::util::SplitMix64;
use crate::yarn::scheduler::{CapacityScheduler, QueueConf, SchedNode};
use crate::yarn::{ContainerRequest, Resource};

use super::{stats_from, Stats};

/// Shape of a generated scenario.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub nodes: u32,
    pub queues: usize,
    pub jobs: usize,
    /// Arrival rounds: jobs arrive uniformly over `[0, rounds)` and the
    /// loop keeps running until every container has been released.
    pub rounds: u64,
    /// Fraction of nodes carrying the `gpu` label (and of jobs asking
    /// for it).
    pub gpu_fraction: f64,
    pub seed: u64,
}

impl ClusterSpec {
    /// The ISSUE 9 operating point: 10k nodes, 1k queues, 5k gang jobs.
    pub fn large() -> ClusterSpec {
        ClusterSpec { nodes: 10_000, queues: 1_000, jobs: 5_000, rounds: 200, gpu_fraction: 0.1, seed: 0x70_6e_79 }
    }

    /// A proportionally shrunk scenario for `TONY_BENCH_SMOKE=1` runs.
    pub fn smoke() -> ClusterSpec {
        ClusterSpec { nodes: 2_000, queues: 200, jobs: 800, rounds: 60, gpu_fraction: 0.1, seed: 0x70_6e_79 }
    }
}

/// One generated gang job.
#[derive(Debug, Clone)]
pub struct GenJob {
    pub app: ApplicationId,
    pub queue: usize,
    pub arrival_round: u64,
    /// Rounds between a container's grant and its release.
    pub duration_rounds: u64,
    pub asks: Vec<ContainerRequest>,
}

/// A fully generated scenario: nodes, queue tree, and job arrivals
/// sorted by round.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub spec: ClusterSpec,
    pub queues: Vec<QueueConf>,
    pub nodes: Vec<SchedNode>,
    pub jobs: Vec<GenJob>,
    pub total: Resource,
}

impl Scenario {
    pub fn generate(spec: ClusterSpec) -> Scenario {
        let mut rng = SplitMix64::new(spec.seed);

        // Queue tree: guarantees sum to ~1.0 (equal split), bursty
        // ceilings so small queues can borrow — which is what makes the
        // most-underserved-first ordering and headroom checks do real
        // work at 1k queues.
        let cap = 1.0 / spec.queues as f64;
        let queues: Vec<QueueConf> = (0..spec.queues)
            .map(|i| QueueConf::new(&format!("q{i}"), cap, (cap * 8.0).min(1.0)))
            .collect();

        // Node mix: a few memory size classes, a `gpu`-labeled partition.
        let mut nodes = Vec::with_capacity(spec.nodes as usize);
        let mut total = Resource::ZERO;
        let gpu_nodes = (spec.nodes as f64 * spec.gpu_fraction) as u32;
        for i in 0..spec.nodes {
            let mem = *rng.choose(&[32_768u64, 65_536, 131_072]);
            let cores = (mem / 4096) as u32;
            let (label, gpus) =
                if i < gpu_nodes { (Some("gpu".to_string()), 8) } else { (None, 0) };
            let cap = Resource::new(mem, cores, gpus);
            total += cap;
            nodes.push(SchedNode::new(i, label, cap));
        }

        // Jobs: mostly small gangs (the TonY profile: a PS/worker wave
        // per allocate round), a tail of wide ones, ~gpu_fraction of
        // them GPU jobs pinned to the labeled partition.
        let mut jobs = Vec::with_capacity(spec.jobs);
        for seq in 0..spec.jobs {
            let tasks = match rng.next_below(10) {
                0..=5 => rng.range_u64(1, 4),
                6..=8 => rng.range_u64(4, 16),
                _ => rng.range_u64(16, 64),
            } as u32;
            let gpu_job = rng.chance(spec.gpu_fraction);
            let task = if gpu_job {
                Resource::new(rng.range_u64(1, 8) * 1024, rng.range_u64(1, 4) as u32, 1)
            } else {
                Resource::new(rng.range_u64(1, 16) * 1024, rng.range_u64(1, 8) as u32, 0)
            };
            let mut ask = ContainerRequest::new(task, tasks);
            if gpu_job {
                ask = ask.with_label("gpu");
            }
            jobs.push(GenJob {
                app: ApplicationId { cluster_ts: 1, seq: seq as u64 + 1 },
                queue: rng.next_below(spec.queues as u64) as usize,
                arrival_round: rng.next_below(spec.rounds),
                duration_rounds: rng.range_u64(2, 30),
                asks: vec![ask],
            });
        }
        jobs.sort_by_key(|j| j.arrival_round);

        Scenario { spec, queues, nodes, jobs, total }
    }

    /// A fresh scheduler loaded with this scenario's queues and nodes.
    /// `linear_reference` selects the retained linear scan instead of
    /// the indexes (for baseline and equivalence runs).
    pub fn build_scheduler(&self, linear_reference: bool) -> CapacityScheduler {
        let mut sched = CapacityScheduler::new(self.queues.clone(), self.total);
        sched.set_linear_reference(linear_reference);
        sched.set_nodes(self.nodes.clone());
        sched
    }
}

/// Outcome of one discrete-event run.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Per-`schedule()`-pass latency distribution.
    pub pass: Stats,
    pub rounds: u64,
    pub grants: usize,
    /// Order-sensitive digest of every `(tag, node)` placement — two
    /// runs placed identically iff their digests match, which is how
    /// the benches assert indexed ≡ linear without keeping 100k grants.
    pub placement_digest: u64,
}

/// Drive `sched` through the scenario: inject arrivals, time each
/// `schedule()` pass, release finished containers on their due round.
/// Runs past `spec.rounds` until the cluster fully drains.
pub fn run(scenario: &Scenario, sched: &mut CapacityScheduler) -> RunReport {
    let mut samples: Vec<f64> = Vec::with_capacity(scenario.spec.rounds as usize * 2);
    let mut grants_total = 0usize;
    let mut digest = 0xcbf2_9ce4_8422_2325u64; // FNV-1a offset basis
    fn fnv(v: u64, d: &mut u64) {
        *d ^= v;
        *d = d.wrapping_mul(0x0000_0100_0000_01b3);
    }

    // Containers in flight, keyed by the round they release at.
    // (queue index, node, resource) is all release_container needs.
    let mut in_flight: std::collections::BTreeMap<u64, Vec<(usize, crate::util::ids::NodeId, Resource)>> =
        std::collections::BTreeMap::new();
    let mut next_job = 0usize;
    let mut next_tag = 1u64;
    let mut next_gang = 1u64;
    let qnames: Vec<String> = scenario.queues.iter().map(|q| q.name.clone()).collect();

    let mut round = 0u64;
    loop {
        // 1. Arrivals for this round become gangs.
        while next_job < scenario.jobs.len()
            && scenario.jobs[next_job].arrival_round <= round
        {
            let job = &scenario.jobs[next_job];
            let intake = sched.add_asks_gang(
                job.app,
                &qnames[job.queue],
                &job.asks,
                next_tag,
                Some(next_gang),
            );
            next_tag = intake.next_tag;
            next_gang += 1;
            next_job += 1;
        }

        // 2. One timed allocate round — the only thing in the window.
        let t = Instant::now();
        let grants = sched.schedule();
        samples.push(t.elapsed().as_nanos() as f64);

        // 3. Bookkeeping: digest + release schedule (untimed).
        for g in &grants {
            fnv(g.ask.tag, &mut digest);
            fnv(g.node.0 as u64, &mut digest);
            let job = &scenario.jobs[(g.ask.app.seq - 1) as usize];
            in_flight
                .entry(round + job.duration_rounds)
                .or_default()
                .push((job.queue, g.node, g.ask.resource));
        }
        grants_total += grants.len();

        // 4. Releases due this round go back through the index.
        if let Some(due) = in_flight.remove(&round) {
            for (qi, node, r) in due {
                sched.release_container(&qnames[qi], node, r);
            }
        }

        round += 1;
        let drained =
            next_job >= scenario.jobs.len() && in_flight.is_empty() && sched.pending_count() == 0;
        // Past the arrival horizon a stuck scenario (asks that can never
        // place) must still terminate: give it one horizon of grace.
        if drained || round > scenario.spec.rounds * 4 + 200 {
            break;
        }
    }

    RunReport { pass: stats_from(samples), rounds: round, grants: grants_total, placement_digest: digest }
}

/// Run the scenario end-to-end with a wall-clock budget: returns early
/// (with fewer rounds measured) once `budget` elapses.  Used for the
/// linear baseline at 10k nodes, where a full drain would take minutes.
pub fn run_budgeted(
    scenario: &Scenario,
    sched: &mut CapacityScheduler,
    budget: Duration,
) -> RunReport {
    let start = Instant::now();
    let mut samples: Vec<f64> = Vec::new();
    let mut grants_total = 0usize;
    let mut in_flight: std::collections::BTreeMap<u64, Vec<(usize, crate::util::ids::NodeId, Resource)>> =
        std::collections::BTreeMap::new();
    let mut next_job = 0usize;
    let mut next_tag = 1u64;
    let mut next_gang = 1u64;
    let qnames: Vec<String> = scenario.queues.iter().map(|q| q.name.clone()).collect();
    let mut round = 0u64;
    loop {
        while next_job < scenario.jobs.len()
            && scenario.jobs[next_job].arrival_round <= round
        {
            let job = &scenario.jobs[next_job];
            let intake = sched.add_asks_gang(
                job.app,
                &qnames[job.queue],
                &job.asks,
                next_tag,
                Some(next_gang),
            );
            next_tag = intake.next_tag;
            next_gang += 1;
            next_job += 1;
        }
        let t = Instant::now();
        let grants = sched.schedule();
        samples.push(t.elapsed().as_nanos() as f64);
        for g in &grants {
            let job = &scenario.jobs[(g.ask.app.seq - 1) as usize];
            in_flight
                .entry(round + job.duration_rounds)
                .or_default()
                .push((job.queue, g.node, g.ask.resource));
        }
        grants_total += grants.len();
        if let Some(due) = in_flight.remove(&round) {
            for (qi, node, r) in due {
                sched.release_container(&qnames[qi], node, r);
            }
        }
        round += 1;
        let drained =
            next_job >= scenario.jobs.len() && in_flight.is_empty() && sched.pending_count() == 0;
        if drained || start.elapsed() > budget || round > scenario.spec.rounds * 4 + 200 {
            break;
        }
    }
    RunReport { pass: stats_from(samples), rounds: round, grants: grants_total, placement_digest: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = ClusterSpec { nodes: 50, queues: 5, jobs: 20, rounds: 10, gpu_fraction: 0.2, seed: 42 };
        let a = Scenario::generate(spec.clone());
        let b = Scenario::generate(spec);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.queues, b.queues);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.app, y.app);
            assert_eq!(x.queue, y.queue);
            assert_eq!(x.arrival_round, y.arrival_round);
            assert_eq!(x.asks, y.asks);
        }
    }

    #[test]
    fn small_run_drains_and_matches_linear() {
        let spec = ClusterSpec { nodes: 60, queues: 6, jobs: 40, rounds: 20, gpu_fraction: 0.2, seed: 7 };
        let sc = Scenario::generate(spec);
        let mut indexed = sc.build_scheduler(false);
        let mut linear = sc.build_scheduler(true);
        let ri = run(&sc, &mut indexed);
        let rl = run(&sc, &mut linear);
        assert!(ri.grants > 0, "scenario produced no grants");
        assert_eq!(ri.grants, rl.grants, "indexed and linear grant counts diverge");
        assert_eq!(
            ri.placement_digest, rl.placement_digest,
            "indexed and linear placements diverge"
        );
        indexed.verify_invariants();
    }
}
