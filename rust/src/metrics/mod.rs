//! Live observability plane: bounded time-series storage and Prometheus
//! text-format exposition.
//!
//! The paper (§1) names *lack of monitoring* as one of the four core
//! challenges of orchestrating distributed ML; TonY's production answer
//! is the Portal + Dr. Elephant.  This module is the storage layer that
//! makes a job observable *while it runs* instead of only after the
//! fact:
//!
//! - [`Series`] — a bounded ring buffer of `(t_ms, value)` samples;
//!   memory per task is a hard constant, however long the job runs.
//! - [`Registry`] — per-task series (step, loss, step_ms_avg,
//!   mem_used_mb) folded from executor heartbeats on the AM hot path,
//!   plus sampled per-queue cluster gauges (dominant-share utilization,
//!   pending asks, per-dimension usage) from the CapacityScheduler.
//! - [`PromText`] — a tiny Prometheus text-format builder with proper
//!   label escaping, used by the portal's and gateway's `GET /metrics`.
//!
//! Sampling is rate-limited by `tony.metrics.sample-interval-ms` so a
//! 50 ms heartbeat interval does not write 20 points a second; setting
//! the interval to 0 disables collection entirely (the hot path then
//! returns before taking any lock).
//!
//! # Example
//!
//! ```
//! use tony::metrics::{PromText, Registry};
//!
//! let reg = Registry::new(128, 1);
//! reg.observe_task("worker:0", 5, 2.25, 12.0, 64, true);
//! let series = reg.series_json();
//! assert!(series.at(&["tasks", "worker:0", "loss"]).is_some());
//!
//! let mut prom = PromText::new();
//! prom.header("tony_task_step", "gauge", "Latest training step per task.");
//! prom.sample("tony_task_step", &[("task", "worker:0")], 5.0);
//! assert!(prom.finish().contains("tony_task_step{task=\"worker:0\"} 5"));
//! ```

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Json;
use crate::yarn::Resource;

/// The per-task metrics folded into time series from heartbeats.
pub const TASK_SERIES: &[&str] = &["step", "loss", "step_ms_avg", "mem_used_mb"];

/// The per-queue gauges sampled from the scheduler.
pub const QUEUE_SERIES: &[&str] =
    &["utilization", "pending_asks", "used_mem_mb", "used_vcores", "used_gpus"];

/// A bounded ring buffer of `(t_ms, value)` samples.  Pushing past the
/// capacity evicts the oldest point, so a series never outgrows its
/// configured retention however long the job runs.
#[derive(Debug, Clone, Default)]
pub struct Series {
    points: VecDeque<(u64, f64)>,
    cap: usize,
}

impl Series {
    pub fn new(cap: usize) -> Series {
        Series { points: VecDeque::new(), cap: cap.max(1) }
    }

    pub fn push(&mut self, t_ms: u64, v: f64) {
        if self.points.len() == self.cap {
            self.points.pop_front();
        }
        self.points.push_back((t_ms, v));
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn last(&self) -> Option<(u64, f64)> {
        self.points.back().copied()
    }

    pub fn points(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.points.iter().copied()
    }

    /// At most `n` evenly spaced points, always including the newest one
    /// — what gets persisted into the history store at job completion.
    pub fn downsample(&self, n: usize) -> Vec<(u64, f64)> {
        let len = self.points.len();
        let n = n.max(1);
        if len <= n {
            return self.points.iter().copied().collect();
        }
        if n == 1 {
            return self.last().into_iter().collect();
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // Spread indices over [0, len-1], anchored at both ends.
            // With len > n the indices are strictly increasing, so no
            // dedup is needed (and deduping by timestamp would drop
            // same-millisecond points, including the forced final one).
            let idx = i * (len - 1) / (n - 1);
            out.push(self.points[idx]);
        }
        out
    }

    fn to_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|(t, v)| Json::Arr(vec![Json::from(*t), Json::from(*v)]))
                .collect(),
        )
    }
}

#[derive(Debug, Default)]
struct SeriesSet {
    last_sample_ms: Option<u64>,
    series: BTreeMap<&'static str, Series>,
}

impl SeriesSet {
    /// Rate limit: true when this set is due for another sample.
    fn due(&self, now_ms: u64, interval_ms: u64) -> bool {
        match self.last_sample_ms {
            None => true,
            Some(last) => now_ms.saturating_sub(last) >= interval_ms,
        }
    }

    fn record(&mut self, now_ms: u64, cap: usize, values: &[(&'static str, f64)]) {
        self.last_sample_ms = Some(now_ms);
        for &(name, v) in values {
            self.series.entry(name).or_insert_with(|| Series::new(cap)).push(now_ms, v);
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (name, s) in &self.series {
            j.set(name, s.to_json());
        }
        j
    }
}

#[derive(Debug, Default)]
struct Inner {
    tasks: BTreeMap<String, SeriesSet>,
    queues: BTreeMap<String, SeriesSet>,
}

/// Bounded per-job metrics registry.  One lives inside every
/// [`crate::am::AmState`]; the AM's heartbeat handler folds task metrics
/// into it and the AM monitor loop samples cluster gauges.  The portal
/// and gateway read it concurrently.
#[derive(Debug)]
pub struct Registry {
    inner: Mutex<Inner>,
    start: Instant,
    interval_ms: u64,
    cap: usize,
}

impl Registry {
    /// `retention_points` bounds every ring buffer; `sample_interval_ms`
    /// rate-limits appends (0 disables collection entirely).
    pub fn new(retention_points: usize, sample_interval_ms: u64) -> Registry {
        Registry {
            inner: Mutex::new(Inner::default()),
            start: Instant::now(),
            interval_ms: sample_interval_ms,
            cap: retention_points.max(1),
        }
    }

    /// A registry that records nothing (the `sample-interval-ms = 0`
    /// configuration).
    pub fn disabled() -> Registry {
        Registry::new(1, 0)
    }

    pub fn enabled(&self) -> bool {
        self.interval_ms > 0
    }

    /// Milliseconds since the registry (i.e. the job) started — the time
    /// axis of every series.
    pub fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Fold one task heartbeat into the registry (the AM hot path).
    /// Rate-limited per task; `force` bypasses the limit so a task's
    /// final flush always lands (the last point of the series is exact).
    /// When collection is disabled this returns before taking any lock.
    pub fn observe_task(
        &self,
        task: &str,
        step: u64,
        loss: f64,
        step_ms_avg: f64,
        mem_used_mb: u64,
        force: bool,
    ) {
        if self.interval_ms == 0 {
            return;
        }
        let now_ms = self.now_ms();
        let mut inner = self.inner.lock().unwrap();
        if let Some(set) = inner.tasks.get_mut(task) {
            if !force && !set.due(now_ms, self.interval_ms) {
                return;
            }
        } else {
            inner.tasks.insert(task.to_string(), SeriesSet::default());
        }
        let cap = self.cap;
        inner.tasks.get_mut(task).unwrap().record(
            now_ms,
            cap,
            &[
                ("step", step as f64),
                ("loss", loss),
                ("step_ms_avg", step_ms_avg),
                ("mem_used_mb", mem_used_mb as f64),
            ],
        );
    }

    /// Sample one queue's scheduler gauges (AM monitor loop / gateway).
    pub fn observe_queue(&self, queue: &str, utilization: f64, used: Resource, pending: usize) {
        if self.interval_ms == 0 {
            return;
        }
        let now_ms = self.now_ms();
        let mut inner = self.inner.lock().unwrap();
        if let Some(set) = inner.queues.get(queue) {
            if !set.due(now_ms, self.interval_ms) {
                return;
            }
        } else {
            inner.queues.insert(queue.to_string(), SeriesSet::default());
        }
        let cap = self.cap;
        inner.queues.get_mut(queue).unwrap().record(
            now_ms,
            cap,
            &[
                ("utilization", utilization),
                ("pending_asks", pending as f64),
                ("used_mem_mb", used.memory_mb as f64),
                ("used_vcores", used.vcores as f64),
                ("used_gpus", used.gpus as f64),
            ],
        );
    }

    /// Every stored series as JSON:
    /// `{"tasks": {"worker:0": {"loss": [[t_ms, v], ...], ...}},
    ///   "queues": {"default": {"utilization": [...], ...}}}`.
    pub fn series_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut tasks = Json::obj();
        for (task, set) in &inner.tasks {
            tasks.set(task, set.to_json());
        }
        let mut queues = Json::obj();
        for (queue, set) in &inner.queues {
            queues.set(queue, set.to_json());
        }
        let mut j = Json::obj();
        j.set("tasks", tasks);
        j.set("queues", queues);
        j
    }

    /// Down-sampled copy of every stored series, in the exact JSON
    /// shape of [`Registry::series_json`] (both `tasks` and `queues`
    /// blocks) — what the history store persists at job completion, so
    /// consumers see one stable shape before and after a job finishes.
    pub fn downsampled_json(&self, n: usize) -> Json {
        fn sets_json(sets: &BTreeMap<String, SeriesSet>, n: usize) -> Json {
            let mut out = Json::obj();
            for (name, set) in sets {
                let mut sj = Json::obj();
                for (metric, series) in &set.series {
                    if series.is_empty() {
                        continue;
                    }
                    sj.set(
                        metric,
                        Json::Arr(
                            series
                                .downsample(n)
                                .into_iter()
                                .map(|(t, v)| Json::Arr(vec![Json::from(t), Json::from(v)]))
                                .collect(),
                        ),
                    );
                }
                out.set(name, sj);
            }
            out
        }
        let inner = self.inner.lock().unwrap();
        let mut j = Json::obj();
        j.set("tasks", sets_json(&inner.tasks, n));
        j.set("queues", sets_json(&inner.queues, n));
        j
    }

    /// Points currently stored for one `(task, metric)` series (tests).
    pub fn task_points(&self, task: &str, metric: &str) -> Vec<(u64, f64)> {
        let inner = self.inner.lock().unwrap();
        inner
            .tasks
            .get(task)
            .and_then(|set| set.series.get(metric))
            .map(|s| s.points().collect())
            .unwrap_or_default()
    }
}

// ---------------------------------------------------------------------
// Histograms
// ---------------------------------------------------------------------

/// Explicit bucket bounds (seconds) for the `tony_stage_seconds` stage-
/// latency families: sub-10 ms launches up through multi-minute queue
/// waits.  `+Inf` is implicit.
pub const STAGE_SECONDS_BUCKETS: &[f64] =
    &[0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0];

/// A fixed-bucket histogram in the Prometheus style: cumulative
/// `le`-bucket counts, a running sum, and a total count.  Buckets are
/// upper-inclusive (`v <= bound`), matching Prometheus semantics.
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// Non-cumulative per-bucket counts; the last slot is the overflow
    /// (`+Inf`) bucket.  Rendering accumulates them.
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Histogram {
    /// `bounds` must be sorted ascending (asserted in debug builds).
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bucket bounds must ascend");
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, count: 0 }
    }

    /// The standard stage-latency histogram (seconds).
    pub fn stage_seconds() -> Histogram {
        Histogram::new(STAGE_SECONDS_BUCKETS)
    }

    pub fn observe(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative `(upper_bound, count)` pairs, ending with the `+Inf`
    /// bucket (whose count equals the total).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            let bound = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((bound, acc));
        }
        out
    }
}

// ---------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote, and newline must be backslash-escaped.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Format a sample value: integral values render without a decimal part
/// (Prometheus accepts both; this keeps the output stable and compact).
fn format_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Minimal Prometheus text-format (version 0.0.4) builder.
///
/// Emit a `# HELP`/`# TYPE` header once per metric family via
/// [`PromText::header`], then any number of samples via
/// [`PromText::sample`]; labels are escaped automatically.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    pub fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {}\n", format_value(value)));
    }

    /// Emit one histogram's samples: the cumulative `_bucket` series
    /// (ending in `le="+Inf"`), `_sum`, and `_count`.  Callers emit the
    /// family header once (`kind = "histogram"`) before the first call.
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], h: &Histogram) {
        for (bound, count) in h.cumulative() {
            let le = if bound.is_infinite() { "+Inf".to_string() } else { format_value(bound) };
            let mut l: Vec<(&str, &str)> = labels.to_vec();
            l.push(("le", le.as_str()));
            self.sample(&format!("{name}_bucket"), &l, count as f64);
        }
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Render the `tony_stage_seconds` histogram family from per-stage
/// histograms — shared by the gateway's and the portal's `/metrics` so
/// both agree on names, buckets, and label scheme.
pub fn render_stage_histograms(
    prom: &mut PromText,
    stages: &BTreeMap<&'static str, Histogram>,
) {
    prom.header(
        "tony_stage_seconds",
        "histogram",
        "Job lifecycle stage latency (queued/scheduling/launching/registering/spec-sync/running).",
    );
    for (stage, h) in stages {
        prom.histogram("tony_stage_seconds", &[("stage", stage)], h);
    }
}

/// Append the per-queue scheduler gauges for `rm` to `prom`.  Samples
/// are grouped per metric family (HELP/TYPE immediately followed by
/// every sample of that family), as the Prometheus text format
/// requires.  Shared by the portal and the gateway so both `/metrics`
/// endpoints agree on metric names.
pub fn render_cluster_metrics(prom: &mut PromText, rm: &crate::yarn::ResourceManager) {
    type QueueGet = fn(&crate::yarn::QueueStat) -> f64;
    let families: [(&str, &str, QueueGet); 10] = [
        (
            "tony_queue_utilization",
            "Dominant-share utilization of each queue (used / cluster total).",
            |q| q.utilization,
        ),
        (
            "tony_queue_guaranteed",
            "Guaranteed (preemption-protected) share of each queue.",
            |q| q.guaranteed,
        ),
        (
            "tony_queue_pending_asks",
            "Container asks waiting in each queue.",
            |q| q.pending as f64,
        ),
        (
            "tony_queue_pending_gangs",
            "Gangs waiting whole (all-or-nothing) in each queue.",
            |q| q.pending_gangs as f64,
        ),
        (
            "tony_queue_reservations",
            "Node reservations held by each queue's blocked gangs.",
            |q| q.reservations as f64,
        ),
        ("tony_queue_used_mem_mb", "Memory (MB) in use per queue.", |q| {
            q.used.memory_mb as f64
        }),
        ("tony_queue_used_vcores", "Virtual cores in use per queue.", |q| {
            q.used.vcores as f64
        }),
        ("tony_queue_used_gpus", "GPUs in use per queue.", |q| q.used.gpus as f64),
        (
            "tony_queue_elastic_jobs",
            "Jobs registered as elastic (resizable worker set) per queue.",
            |q| q.elastic_jobs as f64,
        ),
        (
            "tony_queue_elastic_workers",
            "Acknowledged worker count across each queue's elastic jobs.",
            |q| q.elastic_workers as f64,
        ),
    ];
    let stats = rm.queue_stats();
    for (name, help, get) in families {
        prom.header(name, "gauge", help);
        for q in &stats {
            prom.sample(name, &[("queue", &*q.name)], get(q));
        }
    }
    prom.header(
        "tony_queue_preemptions_total",
        "counter",
        "Victim containers preempted from each queue since RM start.",
    );
    for q in &stats {
        prom.sample(
            "tony_queue_preemptions_total",
            &[("queue", &*q.name)],
            q.preemptions as f64,
        );
    }
    prom.header(
        "tony_queue_elastic_grows_total",
        "counter",
        "Workers granted to elastic jobs by grow commands, per queue.",
    );
    for q in &stats {
        prom.sample(
            "tony_queue_elastic_grows_total",
            &[("queue", &*q.name)],
            q.elastic_grows as f64,
        );
    }
    prom.header(
        "tony_queue_elastic_shrinks_total",
        "counter",
        "Workers cooperatively released by elastic shrink commands, per queue.",
    );
    for q in &stats {
        prom.sample(
            "tony_queue_elastic_shrinks_total",
            &[("queue", &*q.name)],
            q.elastic_shrinks as f64,
        );
    }
    let sched = rm.scheduler_stats();
    prom.header(
        "tony_sched_unknown_queue_total",
        "counter",
        "Asks/releases that named an unknown queue (asks fall back to the first queue).",
    );
    prom.sample(
        "tony_sched_unknown_queue_total",
        &[("kind", "ask")],
        sched.unknown_queue_asks as f64,
    );
    prom.sample(
        "tony_sched_unknown_queue_total",
        &[("kind", "release")],
        sched.unknown_queue_releases as f64,
    );
    prom.header(
        "tony_sched_gangs_placed_total",
        "counter",
        "Gangs committed atomically since RM start.",
    );
    prom.sample("tony_sched_gangs_placed_total", &[], sched.gangs_placed as f64);
    prom.header("tony_cluster_nodes_alive", "gauge", "Nodes currently alive in the cluster.");
    prom.sample("tony_cluster_nodes_alive", &[], rm.alive_node_count() as f64);
}

/// Append per-task gauges to `prom`, one metric family at a time (the
/// Prometheus text format requires all samples of a family in a single
/// group, so callers pass *every* row — across all jobs on the gateway
/// — in one call).  Each row is its full label set (e.g. `task`, plus
/// `job`/`id`/`user`/`queue` on the gateway) and the task's latest
/// metrics snapshot.
pub fn render_task_metrics(
    prom: &mut PromText,
    rows: &[(Vec<(String, String)>, crate::framework::TaskMetrics)],
) {
    type TaskGet = fn(&crate::framework::TaskMetrics) -> f64;
    let families: [(&str, &str, TaskGet); 5] = [
        ("tony_task_step", "Latest training step per task.", |m| m.step as f64),
        ("tony_task_loss", "Latest training loss per task.", |m| m.loss as f64),
        ("tony_task_step_ms_avg", "Average step latency (ms) per task.", |m| m.step_ms_avg),
        ("tony_task_mem_used_mb", "Estimated working set (MB) per task.", |m| {
            m.mem_used_mb as f64
        }),
        ("tony_task_updates_applied", "Optimizer updates applied (PS shards).", |m| {
            m.updates_applied as f64
        }),
    ];
    for (name, help, get) in families {
        prom.header(name, "gauge", help);
        for (labels, m) in rows {
            let refs: Vec<(&str, &str)> =
                labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            prom.sample(name, &refs, get(m));
        }
    }
}

/// Build the label rows [`render_task_metrics`] consumes from one job's
/// task snapshot, prefixing each `task` label with `extra` labels.
pub fn task_rows(
    tasks: Vec<(String, crate::framework::TaskMetrics)>,
    extra: &[(&str, &str)],
) -> Vec<(Vec<(String, String)>, crate::framework::TaskMetrics)> {
    tasks
        .into_iter()
        .map(|(task, m)| {
            let mut labels: Vec<(String, String)> = extra
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect();
            labels.push(("task".to_string(), task));
            (labels, m)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_bounds_and_eviction() {
        let mut s = Series::new(4);
        for i in 0..10u64 {
            s.push(i, i as f64);
        }
        assert_eq!(s.len(), 4, "capacity is a hard bound");
        let pts: Vec<_> = s.points().collect();
        assert_eq!(pts, vec![(6, 6.0), (7, 7.0), (8, 8.0), (9, 9.0)], "oldest evicted first");
        assert_eq!(s.last(), Some((9, 9.0)));
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut s = Series::new(100);
        for i in 0..100u64 {
            s.push(i, i as f64);
        }
        let d = s.downsample(5);
        assert_eq!(d.len(), 5);
        assert_eq!(d.first(), Some(&(0, 0.0)), "first point kept");
        assert_eq!(d.last(), Some(&(99, 99.0)), "newest point kept");
        // Small series pass through untouched.
        let mut small = Series::new(8);
        small.push(1, 1.0);
        assert_eq!(small.downsample(5), vec![(1, 1.0)]);
    }

    #[test]
    fn registry_rate_limits_and_forces() {
        let reg = Registry::new(16, 60_000); // one sample a minute
        reg.observe_task("worker:0", 1, 3.0, 10.0, 64, false);
        reg.observe_task("worker:0", 2, 2.5, 10.0, 64, false); // rate-limited away
        assert_eq!(reg.task_points("worker:0", "step").len(), 1);
        reg.observe_task("worker:0", 3, 2.0, 10.0, 64, true); // forced final flush
        let pts = reg.task_points("worker:0", "step");
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].1, 3.0, "forced sample is the newest point");
    }

    #[test]
    fn registry_respects_retention_cap() {
        let reg = Registry::new(3, 1);
        for i in 0..50u64 {
            reg.observe_task("w", i, 0.0, 0.0, 0, true);
        }
        for metric in TASK_SERIES {
            assert!(
                reg.task_points("w", metric).len() <= 3,
                "{metric} outgrew its retention cap"
            );
        }
        assert_eq!(reg.task_points("w", "step").last().unwrap().1, 49.0);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let reg = Registry::disabled();
        assert!(!reg.enabled());
        reg.observe_task("w", 1, 1.0, 1.0, 1, true);
        reg.observe_queue("default", 0.5, Resource::new(1024, 1, 0), 2);
        let j = reg.series_json();
        assert!(j.at(&["tasks", "w"]).is_none());
        assert!(j.at(&["queues", "default"]).is_none());
    }

    #[test]
    fn queue_series_and_json_shape() {
        let reg = Registry::new(8, 1);
        reg.observe_queue("ml", 0.25, Resource::new(2048, 4, 1), 3);
        crate::util::clock::real_sleep(std::time::Duration::from_millis(2));
        reg.observe_queue("ml", 0.5, Resource::new(4096, 8, 2), 0);
        let j = reg.series_json();
        let util = j.at(&["queues", "ml", "utilization"]).and_then(|a| a.as_arr()).unwrap();
        assert_eq!(util.len(), 2);
        assert_eq!(util[1].as_arr().unwrap()[1].as_f64(), Some(0.5));
        let pending = j.at(&["queues", "ml", "pending_asks"]).and_then(|a| a.as_arr()).unwrap();
        assert_eq!(pending[0].as_arr().unwrap()[1].as_f64(), Some(3.0));
    }

    #[test]
    fn prometheus_escaping_and_rendering() {
        let mut prom = PromText::new();
        prom.header("tony_task_loss", "gauge", "help text");
        prom.sample(
            "tony_task_loss",
            &[("task", "weird\"name\\with\nnewline"), ("queue", "ml")],
            1.5,
        );
        prom.sample("tony_task_loss", &[], 3.0);
        let text = prom.finish();
        assert!(text.contains("# HELP tony_task_loss help text"));
        assert!(text.contains("# TYPE tony_task_loss gauge"));
        assert!(
            text.contains(r#"tony_task_loss{task="weird\"name\\with\nnewline",queue="ml"} 1.5"#),
            "{text}"
        );
        assert!(text.contains("tony_task_loss 3\n"), "bare sample + integral formatting: {text}");
    }

    #[test]
    fn task_families_render_as_contiguous_groups() {
        // The Prometheus text format requires every sample of a metric
        // family in one group; with two tasks the old per-task rendering
        // interleaved families.
        let mk = |step: u64| crate::framework::TaskMetrics { step, ..Default::default() };
        let rows = task_rows(
            vec![("worker:0".to_string(), mk(1)), ("worker:1".to_string(), mk(2))],
            &[("job", "demo")],
        );
        let mut prom = PromText::new();
        render_task_metrics(&mut prom, &rows);
        let text = prom.finish();
        let last_step = text.rfind("tony_task_step{").unwrap();
        let first_loss = text.find("tony_task_loss{").unwrap();
        assert!(
            last_step < first_loss,
            "tony_task_step samples must form one contiguous group:\n{text}"
        );
        assert!(text.contains(
            "tony_task_step{job=\"demo\",task=\"worker:1\"} 2"
        ));
    }

    #[test]
    fn histogram_buckets_are_upper_inclusive_and_cumulative() {
        let mut h = Histogram::new(&[0.1, 1.0, 10.0]);
        h.observe(0.1); // exactly on a bound lands in that bucket (le)
        h.observe(0.05);
        h.observe(0.5);
        h.observe(100.0); // overflow -> +Inf only
        let cum = h.cumulative();
        assert_eq!(cum.len(), 4, "three bounds plus +Inf");
        assert_eq!(cum[0], (0.1, 2), "0.05 and the boundary 0.1");
        assert_eq!(cum[1], (1.0, 3));
        assert_eq!(cum[2], (10.0, 3));
        assert!(cum[3].0.is_infinite());
        assert_eq!(cum[3].1, 4, "+Inf bucket counts everything");
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 100.65).abs() < 1e-9);
    }

    #[test]
    fn histogram_prometheus_rendering() {
        let mut h = Histogram::new(&[0.5, 5.0]);
        h.observe(0.2);
        h.observe(7.0);
        let mut prom = PromText::new();
        prom.header("tony_stage_seconds", "histogram", "stage latency");
        prom.histogram("tony_stage_seconds", &[("stage", "queued")], &h);
        let text = prom.finish();
        assert!(text.contains("# TYPE tony_stage_seconds histogram"), "{text}");
        assert!(text.contains("tony_stage_seconds_bucket{stage=\"queued\",le=\"0.5\"} 1"), "{text}");
        assert!(text.contains("tony_stage_seconds_bucket{stage=\"queued\",le=\"5\"} 1"), "{text}");
        assert!(
            text.contains("tony_stage_seconds_bucket{stage=\"queued\",le=\"+Inf\"} 2"),
            "le=+Inf closes the family: {text}"
        );
        assert!(text.contains("tony_stage_seconds_sum{stage=\"queued\"} 7.2"), "{text}");
        assert!(text.contains("tony_stage_seconds_count{stage=\"queued\"} 2"), "{text}");
    }

    #[test]
    fn stage_seconds_buckets_ascend() {
        assert!(STAGE_SECONDS_BUCKETS.windows(2).all(|w| w[0] < w[1]));
        let mut h = Histogram::stage_seconds();
        h.observe(0.3);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn downsampled_json_export() {
        let reg = Registry::new(64, 1);
        for i in 0..20u64 {
            reg.observe_task("worker:0", i, (20 - i) as f64, 5.0, 32, true);
        }
        let j = reg.downsampled_json(4);
        let loss = j
            .at(&["tasks", "worker:0", "loss"])
            .and_then(|a| a.as_arr())
            .expect("loss series exported");
        assert!(loss.len() <= 4);
        let last = loss.last().unwrap().as_arr().unwrap();
        assert_eq!(last[1].as_f64(), Some(1.0), "newest loss kept");
        assert!(j.get("queues").is_some(), "same shape as series_json");
    }
}
