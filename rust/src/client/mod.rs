//! The TonY Client (paper §2.1): the library users call to launch
//! distributed ML jobs.
//!
//! Users describe resources in an XML configuration (see
//! [`crate::tonyconf`]), the client validates it, packages the
//! configuration + program spec into a staging directory (the archive the
//! real client ships to HDFS), submits the application to the scheduler,
//! and then surfaces the AM's tracking/UI URLs and final status.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::am::{run_application_master, AmContext, AmState};
use crate::portal::Portal;
use crate::tinfo;
use crate::tonyconf::JobSpec;
use crate::trace::SpanStore;
use crate::util::ids::ApplicationId;
use crate::xmlconf::Configuration;
use crate::yarn::{AppReport, AppState, ResourceManager, SubmissionContext};

/// Submission knobs (see [`TonyClient::submit_opts`]).
pub struct SubmitOpts {
    /// Start a per-job monitoring portal (the single-job CLI default).
    pub start_portal: bool,
    /// Tracking URL to register with the RM when no portal is started
    /// (the gateway points this at its own `/api/v1/jobs/<id>` route).
    pub tracking_url: Option<String>,
    /// Span store minted by the caller before submission (the gateway
    /// opens the `queued` stage at enqueue time, long before the client
    /// runs).  When absent, the client mints one from the job's
    /// `tony.trace.*` keys at submit.
    pub trace: Option<Arc<SpanStore>>,
}

impl Default for SubmitOpts {
    fn default() -> SubmitOpts {
        SubmitOpts { start_portal: true, tracking_url: None, trace: None }
    }
}

/// A submitted job: the client-side handle.
pub struct JobHandle {
    pub app_id: ApplicationId,
    pub rm: Arc<ResourceManager>,
    pub am_state: Arc<AmState>,
    pub staging_dir: Option<PathBuf>,
    /// The job's monitoring portal (its URL is the RM tracking URL).
    pub portal: Option<Portal>,
    /// The job's lifecycle span store (disabled stores swallow writes,
    /// so this is always present).
    pub trace: Arc<SpanStore>,
}

impl JobHandle {
    pub fn report(&self) -> Option<AppReport> {
        self.rm.app_report(self.app_id)
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self, timeout: Duration) -> Result<AppReport> {
        self.rm.wait_for_completion(self.app_id, timeout)
    }

    /// The first worker's visualization UI URL, once registered (§2.2).
    pub fn ui_url(&self) -> Option<String> {
        self.am_state.ui_url()
    }

    /// Live job status JSON (what the portal serves).
    pub fn status_json(&self) -> crate::json::Json {
        self.am_state.snapshot_json()
    }

    pub fn kill(&self) {
        self.rm.kill_application(self.app_id);
    }

    /// Portal URL (also the RM tracking URL), if the portal started.
    pub fn portal_url(&self) -> Option<String> {
        self.portal.as_ref().map(|p| p.url())
    }

    /// Persist this job's final record into a history store.
    pub fn record_history(
        &self,
        store: &crate::history::HistoryStore,
        wall_ms: u64,
    ) -> anyhow::Result<std::path::PathBuf> {
        let report = self
            .report()
            .ok_or_else(|| anyhow::anyhow!("no report for {}", self.app_id))?;
        store.record_from(self.app_id, &report, &self.am_state, wall_ms)
    }
}

/// The TonY client.
pub struct TonyClient {
    rm: Arc<ResourceManager>,
    /// Where job archives are staged (the HDFS stand-in).
    pub staging_root: PathBuf,
}

impl TonyClient {
    pub fn new(rm: Arc<ResourceManager>) -> TonyClient {
        TonyClient {
            rm,
            staging_root: std::env::temp_dir().join("tony-staging"),
        }
    }

    /// Validate, stage, and submit a job described by `conf`.
    /// `preset_dir` points at the AOT artifacts the tasks will execute.
    pub fn submit(&self, conf: &Configuration, preset_dir: &std::path::Path) -> Result<JobHandle> {
        self.submit_opts(conf, preset_dir, SubmitOpts::default())
    }

    /// Like [`TonyClient::submit`], with knobs for multi-job hosts: the
    /// gateway runs dozens of jobs in one process and serves one central
    /// API, so it suppresses the per-job portal and installs its own
    /// job-status URL as the RM tracking URL instead.
    pub fn submit_opts(
        &self,
        conf: &Configuration,
        preset_dir: &std::path::Path,
        opts: SubmitOpts,
    ) -> Result<JobHandle> {
        let mut opts = opts;
        let spec = Arc::new(JobSpec::from_conf(conf).context("invalid job configuration")?);

        // Fail fast if the job can never fit (the resource-contention
        // story of §1 is about *queuing*, not impossible jobs).
        // Only checked against total capacity; transient contention queues.
        let total_needed = spec.total_task_resources() + spec.am_resource;
        let cluster: crate::yarn::Resource = self
            .rm
            .node_usage()
            .iter()
            .fold(crate::yarn::Resource::ZERO, |acc, (_, _, cap)| acc + *cap);
        if !cluster.fits(&total_needed) {
            bail!(
                "job needs {total_needed} but the cluster only has {cluster}; \
                 reduce instances or memory"
            );
        }
        if !preset_dir.join("meta.json").exists() {
            bail!(
                "artifacts missing at {} (run `make artifacts`)",
                preset_dir.display()
            );
        }

        // Stage the "archive": conf + program metadata, like the client
        // packaging the virtualenv/ML program for the cluster (§2.2).
        let staging = self.stage(&spec, conf)?;

        // The AM's state shares the RM's clock so every deadline in the
        // control plane (liveness, registration, recovery, fallback
        // ticks) is drivable by one manual clock in tests.
        let am_state = Arc::new(AmState::with_clock(&spec, self.rm.clock().clone()));
        let rm = self.rm.clone();
        let am_ctx_state = am_state.clone();
        let preset_dir = preset_dir.to_path_buf();
        let spec_for_am = spec.clone();

        // The AM launchable: what the RM runs in the AM container.
        let rm_for_am = rm.clone();
        let submission = SubmissionContext {
            name: spec.name.clone(),
            queue: spec.queue.clone(),
            am_resource: spec.am_resource,
        };
        let app_id_cell = Arc::new(std::sync::OnceLock::new());
        let app_id_for_am = app_id_cell.clone();
        let am_code: crate::yarn::container::Launchable = Box::new(move |cctx| {
            let app = *app_id_for_am.wait();
            let am = AmContext {
                rm: rm_for_am,
                app,
                job: spec_for_am,
                preset_dir,
                state: am_ctx_state,
            };
            run_application_master(am, &cctx)
        });
        let app_id = rm.submit_application(submission, am_code)?;
        // Trace threading happens before the AM is released (it blocks on
        // the app-id cell), so the AM never races an unset trace slot.
        let trace = opts
            .trace
            .take()
            .unwrap_or_else(|| SpanStore::new(&spec.trace, rm.clock().clone(), app_id.seq));
        am_state.set_trace(&trace);
        rm.register_trace(app_id, &trace);
        let _ = app_id_cell.set(app_id);
        // Central monitoring portal (paper challenge #3); its URL becomes
        // the application's tracking URL, like YARN's proxy link.
        let portal = if opts.start_portal {
            match Portal::start(am_state.clone(), rm.clone()) {
                Ok(p) => {
                    rm.set_tracking_url(app_id, p.url());
                    Some(p)
                }
                Err(e) => {
                    crate::twarn!("client", "portal failed to start: {e:#}");
                    None
                }
            }
        } else {
            if let Some(url) = opts.tracking_url {
                rm.set_tracking_url(app_id, url);
            }
            None
        };
        tinfo!("client", "submitted {} ('{}'), staged at {}", app_id, spec.name, staging.display());
        Ok(JobHandle { app_id, rm, am_state, staging_dir: Some(staging), portal, trace })
    }

    /// Submit from a tony.xml file on disk.
    pub fn submit_xml_file(
        &self,
        xml_path: &std::path::Path,
        preset_dir: &std::path::Path,
    ) -> Result<JobHandle> {
        let conf = Configuration::from_xml_file(xml_path)?;
        self.submit(&conf, preset_dir)
    }

    fn stage(&self, spec: &JobSpec, conf: &Configuration) -> Result<PathBuf> {
        let dir = self
            .staging_root
            .join(format!("{}-{}", spec.name, crate::util::ids::next_seq()));
        std::fs::create_dir_all(&dir)?;
        // lint:allow(config-outside-conf, reason = "tony.xml is the staged conf FILE name (paper idiom), not a config key")
        std::fs::write(dir.join("tony.xml"), conf.to_xml())?;
        std::fs::write(
            dir.join("MANIFEST"),
            format!(
                "name={}\nqueue={}\ntasks={}\npreset={}\n",
                spec.name,
                spec.queue,
                spec.total_tasks(),
                spec.train.preset
            ),
        )?;
        Ok(dir)
    }
}

/// Convenience: submit and wait, returning (report, final chief metrics).
pub fn run_job_blocking(
    rm: &Arc<ResourceManager>,
    conf: &Configuration,
    preset_dir: &std::path::Path,
    timeout: Duration,
) -> Result<(AppReport, Option<crate::framework::TaskMetrics>)> {
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(conf, preset_dir)?;
    let report = handle.wait(timeout)?;
    let metrics = handle.am_state.chief_metrics();
    if report.state != AppState::Finished {
        tinfo!("client", "job ended unsuccessfully: {}", report.diagnostics);
    }
    Ok((report, metrics))
}
