//! Hadoop-style XML configuration (`tony.xml`), with our own XML parser.
//!
//! Paper §2.1: "Users describe in an XML file the resources required by
//! their job."  This module reproduces the `Configuration` idiom from
//! Hadoop/TonY: `<configuration><property><name>..</name><value>..</value>
//! </property>...</configuration>`, with typed getters, defaults, and
//! `${var}` interpolation against previously-set keys.
//!
//! The parser is a deliberately small subset of XML 1.0 sufficient for
//! configuration files: elements, attributes, text, comments, CDATA, and
//! the five predefined entities.  No DTDs, no processing instructions
//! beyond the `<?xml ...?>` prolog.

use std::collections::BTreeMap;
use std::fmt;

// ---------------------------------------------------------------------
// XML tree
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
pub struct Element {
    pub name: String,
    pub attrs: BTreeMap<String, String>,
    pub children: Vec<Node>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Elem(Element),
    Text(String),
}

impl Element {
    pub fn new(name: impl Into<String>) -> Element {
        Element { name: name.into(), attrs: BTreeMap::new(), children: Vec::new() }
    }

    /// Concatenated text content of this element (direct text children).
    pub fn text(&self) -> String {
        let mut out = String::new();
        for c in &self.children {
            if let Node::Text(t) = c {
                out.push_str(t);
            }
        }
        out
    }

    pub fn child(&self, name: &str) -> Option<&Element> {
        self.children.iter().find_map(|c| match c {
            Node::Elem(e) if e.name == name => Some(e),
            _ => None,
        })
    }

    pub fn children_named<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a Element> {
        self.children.iter().filter_map(move |c| match c {
            Node::Elem(e) if e.name == name => Some(e),
            _ => None,
        })
    }

    pub fn add_text_child(&mut self, name: &str, text: &str) {
        let mut e = Element::new(name);
        e.children.push(Node::Text(text.to_string()));
        self.children.push(Node::Elem(e));
    }

    pub fn render(&self) -> String {
        let mut out = String::from("<?xml version=\"1.0\"?>\n");
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        out.push_str(&pad);
        out.push('<');
        out.push_str(&self.name);
        for (k, v) in &self.attrs {
            out.push_str(&format!(" {}=\"{}\"", k, escape(v)));
        }
        if self.children.is_empty() {
            out.push_str("/>\n");
            return;
        }
        let only_text = self.children.iter().all(|c| matches!(c, Node::Text(_)));
        out.push('>');
        if only_text {
            out.push_str(&escape(&self.text()));
        } else {
            out.push('\n');
            for c in &self.children {
                match c {
                    Node::Elem(e) => e.write(out, indent + 1),
                    Node::Text(t) if t.trim().is_empty() => {}
                    Node::Text(t) => {
                        out.push_str(&"  ".repeat(indent + 1));
                        out.push_str(&escape(t.trim()));
                        out.push('\n');
                    }
                }
            }
            out.push_str(&pad);
        }
        out.push_str(&format!("</{}>\n", self.name));
    }
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XmlError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for XmlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xml error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for XmlError {}

pub fn parse_xml(s: &str) -> Result<Element, XmlError> {
    let mut p = XParser { b: s.as_bytes(), i: 0 };
    p.skip_misc();
    let root = p.element()?;
    p.skip_misc();
    if p.i != p.b.len() {
        return Err(p.err("trailing content after root element"));
    }
    Ok(root)
}

struct XParser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> XParser<'a> {
    fn err(&self, msg: &str) -> XmlError {
        XmlError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn starts(&self, s: &str) -> bool {
        self.b[self.i..].starts_with(s.as_bytes())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    /// Skip whitespace, comments, and the <?xml?> prolog between elements.
    fn skip_misc(&mut self) {
        loop {
            self.skip_ws();
            if self.starts("<!--") {
                if let Some(end) = find(self.b, self.i + 4, b"-->") {
                    self.i = end + 3;
                    continue;
                }
                self.i = self.b.len();
                return;
            }
            if self.starts("<?") {
                if let Some(end) = find(self.b, self.i + 2, b"?>") {
                    self.i = end + 2;
                    continue;
                }
                self.i = self.b.len();
                return;
            }
            return;
        }
    }

    fn name(&mut self) -> Result<String, XmlError> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_alphanumeric() || matches!(c, b'_' | b'-' | b'.' | b':'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        if self.i == start {
            return Err(self.err("expected name"));
        }
        Ok(std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string())
    }

    fn element(&mut self) -> Result<Element, XmlError> {
        if self.peek() != Some(b'<') {
            return Err(self.err("expected '<'"));
        }
        self.i += 1;
        let name = self.name()?;
        let mut elem = Element::new(&name);
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'/') => {
                    self.i += 1;
                    if self.peek() != Some(b'>') {
                        return Err(self.err("expected '>' after '/'"));
                    }
                    self.i += 1;
                    return Ok(elem); // self-closing
                }
                Some(b'>') => {
                    self.i += 1;
                    break;
                }
                Some(_) => {
                    let k = self.name()?;
                    self.skip_ws();
                    if self.peek() != Some(b'=') {
                        return Err(self.err("expected '=' in attribute"));
                    }
                    self.i += 1;
                    self.skip_ws();
                    let quote = self.peek().ok_or_else(|| self.err("eof in attribute"))?;
                    if quote != b'"' && quote != b'\'' {
                        return Err(self.err("expected quoted attribute value"));
                    }
                    self.i += 1;
                    let start = self.i;
                    while self.peek().map(|c| c != quote).unwrap_or(false) {
                        self.i += 1;
                    }
                    if self.peek() != Some(quote) {
                        return Err(self.err("unterminated attribute value"));
                    }
                    let raw = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    elem.attrs.insert(k, unescape(raw));
                    self.i += 1;
                }
                None => return Err(self.err("eof in tag")),
            }
        }
        // Content until matching close tag.
        loop {
            if self.starts("<!--") {
                if let Some(end) = find(self.b, self.i + 4, b"-->") {
                    self.i = end + 3;
                    continue;
                }
                return Err(self.err("unterminated comment"));
            }
            if self.starts("<![CDATA[") {
                let start = self.i + 9;
                if let Some(end) = find(self.b, start, b"]]>") {
                    let txt = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    elem.children.push(Node::Text(txt.to_string()));
                    self.i = end + 3;
                    continue;
                }
                return Err(self.err("unterminated CDATA"));
            }
            if self.starts("</") {
                self.i += 2;
                let close = self.name()?;
                if close != name {
                    return Err(self.err(&format!("mismatched close tag: <{name}> vs </{close}>")));
                }
                self.skip_ws();
                if self.peek() != Some(b'>') {
                    return Err(self.err("expected '>' in close tag"));
                }
                self.i += 1;
                return Ok(elem);
            }
            match self.peek() {
                Some(b'<') => {
                    elem.children.push(Node::Elem(self.element()?));
                }
                Some(_) => {
                    let start = self.i;
                    while self.peek().map(|c| c != b'<').unwrap_or(false) {
                        self.i += 1;
                    }
                    let raw = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    if !raw.trim().is_empty() {
                        elem.children.push(Node::Text(unescape(raw)));
                    }
                }
                None => return Err(self.err("eof inside element")),
            }
        }
    }
}

fn find(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if from > hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut rest = s;
    while let Some(amp) = rest.find('&') {
        out.push_str(&rest[..amp]);
        rest = &rest[amp..];
        let semi = match rest.find(';') {
            Some(p) => p,
            None => {
                out.push_str(rest);
                return out;
            }
        };
        let ent = &rest[1..semi];
        match ent {
            "amp" => out.push('&'),
            "lt" => out.push('<'),
            "gt" => out.push('>'),
            "quot" => out.push('"'),
            "apos" => out.push('\''),
            _ if ent.starts_with("#x") || ent.starts_with("#X") => {
                if let Ok(v) = u32::from_str_radix(&ent[2..], 16) {
                    if let Some(c) = char::from_u32(v) {
                        out.push(c);
                    }
                }
            }
            _ if ent.starts_with('#') => {
                if let Ok(v) = ent[1..].parse::<u32>() {
                    if let Some(c) = char::from_u32(v) {
                        out.push(c);
                    }
                }
            }
            _ => {
                // Unknown entity: keep verbatim.
                out.push_str(&rest[..=semi]);
            }
        }
        rest = &rest[semi + 1..];
    }
    out.push_str(rest);
    out
}

// ---------------------------------------------------------------------
// Hadoop-style Configuration
// ---------------------------------------------------------------------

/// Ordered name/value configuration with typed getters and `${key}`
/// variable interpolation, mirroring `org.apache.hadoop.conf.Configuration`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Configuration {
    values: BTreeMap<String, String>,
}

impl Configuration {
    pub fn new() -> Configuration {
        Configuration::default()
    }

    pub fn set(&mut self, key: &str, value: impl Into<String>) -> &mut Self {
        self.values.insert(key.to_string(), value.into());
        self
    }

    pub fn get_raw(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Get with `${var}` interpolation (up to 8 levels, like Hadoop's 20).
    pub fn get(&self, key: &str) -> Option<String> {
        self.get_raw(key).map(|v| self.interpolate(v, 8))
    }

    fn interpolate(&self, s: &str, depth: u32) -> String {
        if depth == 0 || !s.contains("${") {
            return s.to_string();
        }
        let mut out = String::new();
        let mut rest = s;
        while let Some(start) = rest.find("${") {
            out.push_str(&rest[..start]);
            match rest[start + 2..].find('}') {
                Some(end) => {
                    let var = &rest[start + 2..start + 2 + end];
                    match self.get_raw(var) {
                        Some(v) => out.push_str(&self.interpolate(v, depth - 1)),
                        None => out.push_str(&format!("${{{var}}}")),
                    }
                    rest = &rest[start + 2 + end + 1..];
                }
                None => {
                    out.push_str(rest);
                    return out;
                }
            }
        }
        out.push_str(rest);
        out
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.trim().parse().ok()).unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|v| v.trim().parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.trim().parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key).as_deref().map(str::trim) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    /// Parse a byte-size value like "4g" (see `util::bytes`).
    pub fn get_size(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| crate::util::bytes::parse_size(&v))
            .unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Keys beginning with a prefix, e.g. every `tony.worker.*` setting.
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = (&'a str, &'a str)> {
        self.values
            .iter()
            .filter(move |(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Merge another configuration over this one (other wins).
    pub fn merge(&mut self, other: &Configuration) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn from_xml_str(s: &str) -> Result<Configuration, XmlError> {
        let root = parse_xml(s)?;
        if root.name != "configuration" {
            return Err(XmlError {
                pos: 0,
                msg: format!("root element must be <configuration>, got <{}>", root.name),
            });
        }
        let mut conf = Configuration::new();
        for prop in root.children_named("property") {
            let name = prop.child("name").map(|e| e.text());
            let value = prop.child("value").map(|e| e.text());
            match (name, value) {
                (Some(n), Some(v)) if !n.trim().is_empty() => {
                    conf.set(n.trim(), v.trim().to_string());
                }
                _ => {
                    return Err(XmlError {
                        pos: 0,
                        msg: "property requires <name> and <value>".to_string(),
                    })
                }
            }
        }
        Ok(conf)
    }

    pub fn from_xml_file(path: &std::path::Path) -> anyhow::Result<Configuration> {
        let text = std::fs::read_to_string(path)?;
        Ok(Configuration::from_xml_str(&text)?)
    }

    pub fn to_xml(&self) -> String {
        let mut root = Element::new("configuration");
        for (k, v) in &self.values {
            let mut prop = Element::new("property");
            prop.add_text_child("name", k);
            prop.add_text_child("value", v);
            root.children.push(Node::Elem(prop));
        }
        root.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"<?xml version="1.0"?>
<!-- a tony job file -->
<configuration>
  <property>
    <name>tony.worker.instances</name>
    <value>4</value>
  </property>
  <property>
    <name>tony.worker.memory</name>
    <value>4g</value>
  </property>
  <property>
    <name>tony.application.name</name>
    <value>mnist &amp; friends</value>
  </property>
</configuration>"#;

    #[test]
    fn parse_sample_conf() {
        let c = Configuration::from_xml_str(SAMPLE).unwrap();
        assert_eq!(c.get_u32("tony.worker.instances", 0), 4);
        assert_eq!(c.get_size("tony.worker.memory", 0), 4 << 30);
        assert_eq!(c.get("tony.application.name").unwrap(), "mnist & friends");
    }

    #[test]
    fn xml_round_trip() {
        let c = Configuration::from_xml_str(SAMPLE).unwrap();
        let xml = c.to_xml();
        let c2 = Configuration::from_xml_str(&xml).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn interpolation() {
        let mut c = Configuration::new();
        c.set("base.dir", "/data");
        c.set("out.dir", "${base.dir}/out");
        c.set("deep", "${out.dir}/x");
        assert_eq!(c.get("deep").unwrap(), "/data/out/x");
        c.set("cycle", "${cycle}");
        // Cycles terminate (depth-bounded), leaving the unresolved var.
        assert!(c.get("cycle").unwrap().contains("cycle"));
    }

    #[test]
    fn missing_var_left_verbatim() {
        let mut c = Configuration::new();
        c.set("a", "${nope}/x");
        assert_eq!(c.get("a").unwrap(), "${nope}/x");
    }

    #[test]
    fn attributes_and_self_closing() {
        let e = parse_xml(r#"<a x="1" y='2'><b/><c>t</c></a>"#).unwrap();
        assert_eq!(e.attrs["x"], "1");
        assert_eq!(e.attrs["y"], "2");
        assert!(e.child("b").unwrap().children.is_empty());
        assert_eq!(e.child("c").unwrap().text(), "t");
    }

    #[test]
    fn cdata_and_entities() {
        let e = parse_xml("<a><![CDATA[1 < 2 & 3]]></a>").unwrap();
        assert_eq!(e.text(), "1 < 2 & 3");
        let e = parse_xml("<a>&#65;&#x42;&amp;</a>").unwrap();
        assert_eq!(e.text(), "AB&");
    }

    #[test]
    fn parse_errors() {
        assert!(parse_xml("<a><b></a>").is_err());
        assert!(parse_xml("<a>").is_err());
        assert!(parse_xml("<a></a><b></b>").is_err());
        assert!(Configuration::from_xml_str("<notconf/>").is_err());
        assert!(Configuration::from_xml_str(
            "<configuration><property><name>x</name></property></configuration>"
        )
        .is_err());
    }

    #[test]
    fn typed_getters_defaults() {
        let c = Configuration::new();
        assert_eq!(c.get_u64("missing", 7), 7);
        assert!(c.get_bool("missing", true));
        let mut c = Configuration::new();
        c.set("b", "yes");
        assert!(c.get_bool("b", false));
    }
}
