//! Resource contention: TonY/YARN vs ad-hoc launch scripts (paper §1).
//!
//! Reproduces the paper's motivation table: co-tenant jobs on a shared
//! pool, sweeping oversubscription.  The ad-hoc pool loses jobs to OOM
//! and config errors; the managed path queues instead and finishes
//! everything.
//!
//! ```sh
//! cargo run --release --example contention
//! ```

use tony::baseline::{run_adhoc_pool, run_managed_pool, synthetic_jobs, AdhocOutcome, AdhocParams};
use tony::yarn::Resource;

fn main() {
    tony::util::logging::init_from_env();
    let hosts = vec![Resource::mem_cores(8192, 8); 4]; // 32 GiB pool
    println!("pool: 4 hosts x 8 GiB; jobs: 2 tasks x 2 GiB each, 60 s runtime\n");
    println!(
        "{:>6} {:>8} | {:>9} {:>6} {:>8} | {:>9} {:>12}",
        "jobs", "demand", "adhoc-ok", "oom", "misconf", "tony-ok", "tony-makespan"
    );

    for n_jobs in [4u32, 8, 12, 16, 24, 32] {
        let jobs = synthetic_jobs(n_jobs, 2, 2048, 60_000);
        let demand = (n_jobs as u64 * 2 * 2048) as f64 / (4.0 * 8192.0);

        // Average the ad-hoc outcome over several seeds (users place by
        // hand differently every time).
        let mut ok = 0usize;
        let mut oom = 0usize;
        let mut mis = 0usize;
        let seeds = 20u64;
        for seed in 0..seeds {
            let params = AdhocParams { per_host_config_error: 0.02, seed };
            for r in run_adhoc_pool(&hosts, &jobs, &params) {
                match r.outcome {
                    AdhocOutcome::Succeeded => ok += 1,
                    AdhocOutcome::OomKilled => oom += 1,
                    AdhocOutcome::Misconfigured => mis += 1,
                }
            }
        }
        let tot = (n_jobs as usize * seeds as usize) as f64;

        let managed = run_managed_pool(&hosts, &jobs);
        let tony_ok = managed.iter().filter(|r| r.outcome == AdhocOutcome::Succeeded).count();
        let makespan = managed.iter().map(|r| r.finished_at_ms).max().unwrap_or(0);

        println!(
            "{:>6} {:>7.0}% | {:>8.1}% {:>5.1}% {:>7.1}% | {:>8.1}% {:>11.1}s",
            n_jobs,
            demand * 100.0,
            ok as f64 / tot * 100.0,
            oom as f64 / tot * 100.0,
            mis as f64 / tot * 100.0,
            tony_ok as f64 / n_jobs as f64 * 100.0,
            makespan as f64 / 1e3,
        );
    }
    println!(
        "\nTonY keeps success at 100% by queuing (makespan grows); the ad-hoc pool \
         sheds jobs via OOM as oversubscription rises — the paper's §1 story."
    );
}
