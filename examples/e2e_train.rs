//! End-to-end validation driver (DESIGN.md §5): train the transformer LM
//! through the FULL stack — client → RM → AM → TaskExecutors → PS/worker
//! TCP protocol → PJRT-executed AOT artifacts — for a few hundred steps,
//! log the loss curve, inject a mid-run worker kill to demonstrate
//! checkpoint-restore, and write the run record EXPERIMENTS.md cites.
//!
//! ```sh
//! make artifacts PRESETS=tiny,small
//! cargo run --release --example e2e_train -- [preset] [steps] [workers] [ps]
//! # defaults: small 300 2 2
//! ```

use std::io::Write;
use std::time::{Duration, Instant};

use tony::chaos::{ChaosInjector, Fault};
use tony::client::TonyClient;
use tony::portal::Portal;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

fn main() -> anyhow::Result<()> {
    tony::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(|s| s.as_str()).unwrap_or("small").to_string();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let ps: u32 = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);

    let artifacts = std::path::PathBuf::from(format!("artifacts/{preset}"));
    anyhow::ensure!(
        artifacts.join("meta.json").exists(),
        "run `make artifacts PRESETS={preset}` first"
    );
    let meta = tony::runtime::ArtifactMeta::load(&artifacts)?;
    println!(
        "== e2e: preset={preset} ({} params), {steps} steps, {workers} workers + {ps} ps ==",
        meta.n_params
    );

    // 6-node cluster.
    let rm = ResourceManager::start_uniform(6, Resource::new(8192, 8, 0));
    let ckpt = std::env::temp_dir().join(format!("tony-e2e-{preset}"));
    let _ = std::fs::remove_dir_all(&ckpt);

    let conf = JobConfBuilder::new("e2e-train")
        .instances("worker", workers)
        .memory("worker", "2g")
        .instances("ps", ps)
        .memory("ps", "2g")
        .train(artifacts.to_str().unwrap(), &preset, steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "50")
        .set("tony.train.eval-every", "50")
        .set("tony.train.lr", "0.001")
        .set("tony.application.max-attempts", "3")
        .build();

    let t0 = Instant::now();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &artifacts)?;
    let portal = Portal::start(handle.am_state.clone(), rm.clone())?;
    println!("portal: {} (open /losses for the live curve)", portal.url());

    // Mid-run fault: kill worker 1 around 40% of the run to demonstrate
    // the §2.2 teardown → relaunch → checkpoint-restore loop.
    let kill_at = (steps * 2) / 5;
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask { task_type: "worker".into(), index: workers - 1, after_step: kill_at }],
    );

    let report = handle.wait(Duration::from_secs(3600))?;
    let records = chaos.join();
    let wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(report.state == AppState::Finished, "job failed: {}", report.diagnostics);

    let m = handle.am_state.chief_metrics().unwrap();
    let attempts = handle.am_state.attempt();
    println!(
        "finished in {wall:.1}s over {attempts} attempt(s); final loss {:.4}, eval {:.4}",
        m.loss, m.eval_loss
    );
    println!("tokens trained: {} ({:.0} tokens/s)", m.tokens_done, m.tokens_done as f64 / wall);
    for r in &records {
        println!(
            "fault injected at t+{}ms (chief step {}): {:?}",
            r.injected_at_ms, r.chief_step_at_injection, r.fault
        );
    }

    // Persist the loss curve + run record.
    std::fs::create_dir_all("runs")?;
    let csv_path = format!("runs/e2e_{preset}_loss.csv");
    let mut csv = std::fs::File::create(&csv_path)?;
    writeln!(csv, "step,loss")?;
    for (s, l) in &m.loss_history {
        writeln!(csv, "{s},{l}")?;
    }
    let rec_path = format!("runs/e2e_{preset}_record.md");
    let mut rec = std::fs::File::create(&rec_path)?;
    writeln!(
        rec,
        "# e2e run record\n\n- preset: {preset} ({} params)\n- topology: {workers} workers + {ps} ps (sync)\n\
         - steps: {steps} (fault at step {kill_at}, attempts used: {attempts})\n\
         - wall: {wall:.1}s, {:.2} steps/s, {:.0} tokens/s\n- first loss: {:.4}\n- final loss: {:.4}\n\
         - final eval loss: {:.4}\n- loss curve: {csv_path}\n",
        meta.n_params,
        steps as f64 / wall,
        m.tokens_done as f64 / wall,
        m.loss_history.first().map(|x| x.1).unwrap_or(f32::NAN),
        m.loss,
        m.eval_loss
    )?;
    println!("wrote {rec_path} and {csv_path}");

    let first = m.loss_history.first().map(|x| x.1).unwrap_or(f32::NAN);
    anyhow::ensure!(
        m.loss < first,
        "loss did not decrease: {first} -> {}",
        m.loss
    );
    let _ = std::fs::remove_dir_all(&ckpt);
    Ok(())
}
