//! Fault-tolerance walkthrough: watch the AM *surgically* recover from a
//! task kill AND a node kill — replacing only the dead containers while
//! survivors keep running — printing the recovery timeline.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```
//!
//! Uses `artifacts/tiny` when present; otherwise falls back to the
//! synthetic preset (sim backend), so it runs in offline CI too.

use std::time::{Duration, Instant};

use tony::chaos::{ChaosInjector, Fault};
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, NodeSpec, QueueConf, Resource, ResourceManager};

fn main() -> anyhow::Result<()> {
    tony::util::logging::init_from_env();
    let real = std::path::PathBuf::from("artifacts/tiny");
    let artifacts = if real.join("meta.json").exists() {
        real
    } else {
        println!("artifacts/tiny missing; using the synthetic preset (sim backend)");
        tony::runtime::synthetic::default_dir()?
    };

    // Node 0 fits only the AM, so node kills never take the master down.
    let specs = vec![
        NodeSpec::new(0, Resource::new(1024, 2, 0)),
        NodeSpec::new(1, Resource::new(8192, 8, 0)),
        NodeSpec::new(2, Resource::new(8192, 8, 0)),
        NodeSpec::new(3, Resource::new(8192, 8, 0)),
    ];
    let rm = ResourceManager::start(specs, QueueConf::default_only());
    let ckpt = std::env::temp_dir().join(format!(
        "tony-ft-example-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&ckpt);

    let steps = 24u64;
    let conf = JobConfBuilder::new("ft-demo")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(artifacts.to_str().unwrap(), "tiny", steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "4")
        .set("tony.application.max-attempts", "4")
        .build();

    let t0 = Instant::now();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &artifacts)?;

    println!("schedule: kill worker:1 after step 6, then kill node1 after step 14");
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![
            Fault::KillTask { task_type: "worker".into(), index: 1, after_step: 6 },
            Fault::KillNode { node: 1, after_step: 14 },
        ],
    );

    // Timeline printer.
    let state = handle.am_state.clone();
    let timeline = std::thread::spawn(move || {
        let mut last = (0u32, 0u32, String::new(), 0u64);
        loop {
            let phase = format!("{:?}", state.phase());
            let attempt = state.attempt();
            let version = state.spec_version();
            let step = state.chief_metrics().map(|m| m.step).unwrap_or(0);
            if (attempt, version, phase.clone(), step) != last {
                println!(
                    "[t+{:>6.1}s] attempt={attempt} spec_v{version} phase={phase} chief_step={step}",
                    t0.elapsed().as_secs_f64()
                );
                last = (attempt, version, phase.clone(), step);
            }
            if phase == "Succeeded" || phase == "Failed" {
                break;
            }
            tony::util::clock::real_sleep(Duration::from_millis(100));
        }
    });

    let report = handle.wait(Duration::from_secs(900))?;
    let records = chaos.join();
    let _ = timeline.join();

    println!("\nfinal: {:?} in {:.1}s — {}", report.state, t0.elapsed().as_secs_f64(), report.diagnostics);
    for r in &records {
        println!(
            "  fault fired at t+{}ms (chief step {}, spec v{}): {:?}",
            r.injected_at_ms, r.chief_step_at_injection, r.version_at_injection, r.fault
        );
    }
    println!(
        "  attempts used: {} (surgical recoveries: {})",
        handle.am_state.attempt(),
        handle.am_state.recoveries()
    );
    println!("  alive nodes:   {}/{}", rm.alive_node_count(), rm.node_count());
    let m = handle.am_state.chief_metrics().unwrap();
    println!("  chief reached step {} (target {steps}); final loss {:.4}", m.step, m.loss);
    anyhow::ensure!(report.state == AppState::Finished, "expected recovery");
    anyhow::ensure!(
        handle.am_state.recoveries() >= 1,
        "expected at least one surgical recovery"
    );
    let _ = std::fs::remove_dir_all(&ckpt);
    Ok(())
}
