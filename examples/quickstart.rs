//! Quickstart: the smallest end-to-end TonY run.
//!
//! Boots a simulated 3-node YARN cluster, submits a distributed training
//! job (2 workers + 1 parameter server, tiny transformer preset), waits
//! for it, and prints the portal status plus the Dr. Elephant report.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::time::Duration;

use tony::client::TonyClient;
use tony::portal::http_get;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{Resource, ResourceManager};

fn main() -> anyhow::Result<()> {
    tony::util::logging::init_from_env();
    let artifacts = std::path::Path::new("artifacts/tiny");
    anyhow::ensure!(
        artifacts.join("meta.json").exists(),
        "run `make artifacts` first (artifacts/tiny missing)"
    );

    // 1. A cluster: 3 nodes x 8 GiB x 8 cores.
    let rm = ResourceManager::start_uniform(3, Resource::new(8192, 8, 0));

    // 2. A job description — the same knobs a tony.xml would carry.
    let ckpt = std::env::temp_dir().join("tony-quickstart-ckpt");
    let _ = std::fs::remove_dir_all(&ckpt);
    let conf = JobConfBuilder::new("quickstart")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train("artifacts/tiny", "tiny", 10)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.eval-every", "5")
        .build();
    println!("--- tony.xml equivalent ---\n{}", conf.to_xml());

    // 3. Submit through the TonY client.
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, artifacts)?;
    println!("submitted {}", handle.app_id);

    // 4. The central portal (monitoring, §1 challenge #3) is started by
    // the client and doubles as the RM tracking URL.
    let portal_url = handle.portal_url().expect("portal running");
    println!("portal: {portal_url}");

    // 5. While it runs, hit the chief's UI (TensorBoard stand-in, §2.2) —
    // the URL flows chief executor -> AM -> client.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    while handle.ui_url().is_none() && std::time::Instant::now() < deadline {
        tony::util::clock::real_sleep(Duration::from_millis(50));
    }
    if let Some(ui) = handle.ui_url() {
        if let Ok((code, body)) = http_get(&ui) {
            println!("chief UI ({ui}) -> HTTP {code}\n{body}");
        }
    }

    // 6. Wait and inspect.
    let report = handle.wait(Duration::from_secs(300))?;
    println!("state: {:?} — {}", report.state, report.diagnostics);
    let (code, body) = http_get(&format!("{portal_url}/status"))?;
    println!("portal /status -> HTTP {code}\n{body}");

    let metrics = handle.am_state.chief_metrics().unwrap();
    println!(
        "trained {} steps; final loss {:.4} (random-init baseline ~{:.2})",
        metrics.step,
        metrics.loss,
        (256f32).ln()
    );
    let _ = std::fs::remove_dir_all(&ckpt);
    Ok(())
}
