//! Workflow-manager integration (paper §2.1): a distributed training job
//! as one node of a larger pipeline — data-prep → train (TonY job type)
//! → evaluate → deploy — on the Azkaban-role DAG engine.
//!
//! ```sh
//! cargo run --release --example workflow_pipeline
//! ```

use std::time::Duration;

use tony::tonyconf::JobConfBuilder;
use tony::workflow::{JobStatus, Workflow};
use tony::yarn::{Resource, ResourceManager};

fn main() -> anyhow::Result<()> {
    tony::util::logging::init_from_env();
    let artifacts = std::path::Path::new("artifacts/tiny");
    anyhow::ensure!(
        artifacts.join("meta.json").exists(),
        "run `make artifacts` first"
    );
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));

    let work = std::env::temp_dir().join("tony-wf-example");
    let _ = std::fs::remove_dir_all(&work);
    std::fs::create_dir_all(&work)?;
    let corpus_path = work.join("corpus.txt");
    let ckpt = work.join("ckpt");
    let model_out = work.join("model-release");

    let conf = JobConfBuilder::new("wf-train")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(artifacts.to_str().unwrap(), "tiny", 10)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "5")
        .build();

    let mut wf = Workflow::new("ml-pipeline");
    // 1. Data prep (the Spark/MapReduce stand-in): generate a corpus file.
    {
        let corpus_path = corpus_path.clone();
        wf.add_command("data-prep", &[], move || {
            let c = tony::data::SyntheticCorpus::new(256, 7);
            let toks = c.sequence(0, 0, 0, 64 * 1024);
            std::fs::write(&corpus_path, tony::data::decode_bytes(&toks))?;
            println!("[data-prep] wrote {} bytes", std::fs::metadata(&corpus_path)?.len());
            Ok(())
        });
    }
    // 2. Distributed training via the TonY job-type plugin.
    wf.add_tony_job("train", &["data-prep"], conf, artifacts);
    // 3. Evaluate: load the final checkpoint and sanity-check it.
    {
        let ckpt = ckpt.clone();
        wf.add_command("evaluate", &["train"], move || {
            let store = tony::checkpoint::CheckpointStore::new(&ckpt);
            let latest = store
                .latest()?
                .ok_or_else(|| anyhow::anyhow!("no checkpoint produced"))?;
            anyhow::ensure!(latest.params.iter().all(|p| p.is_finite()));
            println!(
                "[evaluate] checkpoint step {} with {} finite params — OK",
                latest.step,
                latest.params.len()
            );
            Ok(())
        });
    }
    // 4. Deploy: "publish" the model artifact.
    {
        let ckpt = ckpt.clone();
        let model_out = model_out.clone();
        wf.add_command("deploy", &["evaluate"], move || {
            std::fs::create_dir_all(&model_out)?;
            let store = tony::checkpoint::CheckpointStore::new(&ckpt);
            let latest = store.latest()?.unwrap();
            std::fs::write(model_out.join("model.tony"), latest.encode())?;
            println!("[deploy] published to {}", model_out.display());
            Ok(())
        });
    }

    let records = wf.run(&rm, Duration::from_secs(600))?;
    println!("\npipeline results:");
    println!("{:<12} {:<10} {:>8} {:>9}", "job", "status", "attempts", "ms");
    for r in &records {
        println!("{:<12} {:<10?} {:>8} {:>9}", r.name, r.status, r.attempts, r.duration_ms);
    }
    anyhow::ensure!(
        records.iter().all(|r| r.status == JobStatus::Succeeded),
        "pipeline failed"
    );
    let _ = std::fs::remove_dir_all(&work);
    Ok(())
}
