"""Analytic TPU performance model for the Pallas kernels (L1 §Perf).

Pallas on this testbed runs interpret=True (CPU), so wallclock is not a
TPU proxy.  Instead we estimate, per (block_q, block_k) configuration:

- VMEM footprint per program (must fit ~16 MiB/core with double-buffering),
- MXU utilization: fraction of each matmul tile that fills the 128x128
  systolic array,
- HBM traffic per attention head (the flash refetch factor vs naive), and
- an arithmetic-intensity-based roofline estimate for a v4-class core
  (275 TFLOP/s bf16, 1.2 TB/s HBM).

Run ``python -m compile.kernels.estimate`` to print the block-shape sweep
table recorded in EXPERIMENTS.md §Perf; test_estimate.py asserts the
invariants (chosen config fits VMEM, utilization maximal among fits).
"""

import dataclasses

MXU = 128                      # systolic array edge
VMEM_BYTES = 16 * 2**20        # per-core VMEM
PEAK_FLOPS = 275e12            # v4-class bf16 peak
HBM_BW = 1.2e12                # bytes/s


@dataclasses.dataclass(frozen=True)
class AttnShape:
    seq: int
    d_head: int


@dataclasses.dataclass(frozen=True)
class BlockEstimate:
    block_q: int
    block_k: int
    vmem_bytes: int
    fits_vmem: bool
    mxu_utilization: float
    hbm_bytes_per_head: int
    flops_per_head: float
    arithmetic_intensity: float
    est_tflops: float

    @property
    def roofline_fraction(self) -> float:
        return self.est_tflops * 1e12 / PEAK_FLOPS


def _tile_util(rows: int, cols: int) -> float:
    """Fraction of the MXU filled by an (rows x cols) matmul tile."""
    def eff(n):
        full, rem = divmod(n, MXU)
        tiles = full + (1 if rem else 0)
        return n / (tiles * MXU)
    return eff(rows) * eff(cols)


def estimate_attention(shape: AttnShape, block_q: int, block_k: int,
                       dtype_bytes: int = 4) -> BlockEstimate:
    s, d = shape.seq, shape.d_head
    bq, bk = min(block_q, s), min(block_k, s)

    # VMEM per program: Q tile + K tile + V tile + acc + m/l rows, double-
    # buffered K/V streams (x2).
    vmem = dtype_bytes * (bq * d + 2 * 2 * bk * d + bq * d + 2 * bq)
    # Two matmuls per inner tile: (bq x d)@(d x bk) and (bq x bk)@(bk x d).
    util = 0.5 * (_tile_util(bq, bk) * _tile_util_inner(d)
                  + _tile_util(bq, d) * _tile_util_inner(bk))
    # Flash HBM traffic per (b,h): Q once, K/V once per q-row-block pass is
    # avoided by the online softmax -> K/V read once per q block.
    n_qb = (s + bq - 1) // bq
    hbm = dtype_bytes * (s * d        # Q
                         + n_qb * 2 * s * d   # K+V streamed per q block
                         + s * d)     # O
    flops = 4.0 * s * s * d  # 2 matmuls x 2 flops, causal ~ /2 skipped (cons.)
    ai = flops / hbm
    est = min(PEAK_FLOPS * util, ai * HBM_BW) / 1e12
    return BlockEstimate(
        block_q=bq,
        block_k=bk,
        vmem_bytes=vmem,
        fits_vmem=vmem <= VMEM_BYTES,
        mxu_utilization=util,
        hbm_bytes_per_head=hbm,
        flops_per_head=flops,
        arithmetic_intensity=ai,
        est_tflops=est,
    )


def _tile_util_inner(k: int) -> float:
    """Contraction-dimension fill of the MXU."""
    full, rem = divmod(k, MXU)
    tiles = full + (1 if rem else 0)
    return k / (tiles * MXU)


def sweep(shape: AttnShape, blocks=(32, 64, 128, 256)):
    out = []
    for bq in blocks:
        for bk in blocks:
            out.append(estimate_attention(shape, bq, bk))
    return out


def best_config(shape: AttnShape, blocks=(32, 64, 128, 256)) -> BlockEstimate:
    candidates = [e for e in sweep(shape, blocks) if e.fits_vmem]
    return max(candidates, key=lambda e: (e.est_tflops, -e.vmem_bytes))


def main():
    for name, shape in [("small (s=128,d=32)", AttnShape(128, 32)),
                        ("large (s=256,d=64)", AttnShape(256, 64)),
                        ("long  (s=2048,d=64)", AttnShape(2048, 64))]:
        print(f"\n== {name} ==")
        print(f"{'bq':>5} {'bk':>5} {'vmem-KiB':>9} {'fits':>5} "
              f"{'mxu%':>6} {'AI':>6} {'est-TF':>7} {'roof%':>6}")
        for e in sweep(shape):
            print(f"{e.block_q:>5} {e.block_k:>5} {e.vmem_bytes >> 10:>9} "
                  f"{str(e.fits_vmem):>5} {e.mxu_utilization * 100:>5.1f} "
                  f"{e.arithmetic_intensity:>6.1f} {e.est_tflops:>7.1f} "
                  f"{e.roofline_fraction * 100:>5.1f}")
        b = best_config(shape)
        print(f"best: bq={b.block_q} bk={b.block_k} "
              f"-> {b.est_tflops:.1f} TFLOP/s ({b.roofline_fraction * 100:.0f}% of peak)")


if __name__ == "__main__":
    main()
