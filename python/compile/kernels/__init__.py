"""Layer-1 Pallas kernels and their pure-jnp oracles."""

from .adam import adam_update  # noqa: F401
from .flash_attention import flash_attention, flash_lse  # noqa: F401
