"""Flash-attention as a Pallas kernel (forward + custom-VJP backward).

This is the Layer-1 compute hot-spot of the workload TonY orchestrates: the
attention inner loop of the transformer LM defined in ``compile.model``.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the CUDA
flash-attention schedule (threadblocks over Q tiles, K/V streamed through
shared memory) is re-expressed for TPU as a Pallas grid over
``(batch, head, q_block)`` with BlockSpecs that pin a ``(block_q, d)`` Q
tile in VMEM and stream ``(block_k, d)`` K/V tiles with an online-softmax
accumulator; matmul tiles are shaped for the MXU (multiples of the 128-lane
register/systolic geometry where the model dims allow).

On this testbed Pallas MUST run with ``interpret=True`` (the CPU PJRT
client cannot execute Mosaic custom-calls), so the kernel lowers to plain
HLO and the TPU efficiency claim is estimated analytically in
EXPERIMENTS.md §Perf.  Correctness vs ``ref.mha_ref`` is enforced by
pytest + hypothesis (python/tests/test_kernel.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default tile sizes. 64 keeps the tiny/small presets exact multiples; the
# block-shape sweep in python/tests/test_block_sweep.py and EXPERIMENTS.md
# §Perf covers {32, 64, 128}.
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k, causal, sm_scale):
    """One (batch, head, q-block) program of the flash forward pass.

    Ref block shapes:
      q_ref: [block_q, d]     -- this program's Q tile (VMEM-resident)
      k_ref: [s, d]           -- full K for the (b, h) slice; streamed in
      v_ref: [s, d]              block_k-sized tiles via pl.dynamic_slice
      o_ref: [block_q, d]     -- output tile
      lse_ref: [block_q]      -- log-sum-exp rows (saved for the backward)
    """
    block_q, d = q_ref.shape
    s = k_ref.shape[0]
    qi = pl.program_id(2)
    q = q_ref[...] * sm_scale

    def body(ki, carry):
        acc, m_i, l_i = carry
        start = ki * block_k
        k = pl.load(k_ref, (pl.dslice(start, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(start, block_k), slice(None)))
        logits = q @ k.T  # [block_q, block_k]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(rows >= cols, logits, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)

    if causal:
        # Only K blocks at or before this Q block's last row contribute.
        num_kb = jnp.minimum(pl.cdiv((qi + 1) * block_q, block_k), pl.cdiv(s, block_k))
    else:
        num_kb = pl.cdiv(s, block_k)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))

    o_ref[...] = acc / l_i[:, None]
    lse_ref[...] = m_i + jnp.log(l_i)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               *, block_k, causal, sm_scale):
    """dQ for one (batch, head, q-block) program.

    dS = P * (dP - delta) with dP = dO @ V^T, P = exp(S - lse);
    dQ = dS @ K * sm_scale.
    """
    block_q, d = q_ref.shape
    s = k_ref.shape[0]
    qi = pl.program_id(2)
    q = q_ref[...] * sm_scale
    do = do_ref[...]
    lse = lse_ref[...]
    delta = delta_ref[...]

    def body(ki, dq):
        start = ki * block_k
        k = pl.load(k_ref, (pl.dslice(start, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(start, block_k), slice(None)))
        logits = q @ k.T
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(rows >= cols, logits, NEG_INF)
        p = jnp.exp(logits - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    if causal:
        num_kb = jnp.minimum(pl.cdiv((qi + 1) * block_q, block_k), pl.cdiv(s, block_k))
    else:
        num_kb = pl.cdiv(s, block_k)
    dq = jax.lax.fori_loop(0, num_kb, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = dq * sm_scale


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                *, block_q, causal, sm_scale):
    """dK, dV for one (batch, head, k-block) program.

    dV = P^T @ dO; dK = dS^T @ Q * sm_scale.
    """
    block_k, d = k_ref.shape
    s = q_ref.shape[0]
    ki = pl.program_id(2)
    k = k_ref[...]
    v = v_ref[...]

    def body(qi, carry):
        dk, dv = carry
        start = qi * block_q
        q = pl.load(q_ref, (pl.dslice(start, block_q), slice(None))) * sm_scale
        do = pl.load(do_ref, (pl.dslice(start, block_q), slice(None)))
        lse = pl.load(lse_ref, (pl.dslice(start, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(start, block_q),))
        logits = q @ k.T  # [block_q, block_k]
        if causal:
            rows = start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(rows >= cols, logits, NEG_INF)
        p = jnp.exp(logits - lse[:, None])
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        dk = dk + ds.T @ q
        return dk, dv

    nqb = pl.cdiv(s, block_q)
    if causal:
        # Q blocks strictly before this K block see none of it.
        first_qb = (ki * block_k) // block_q
    else:
        first_qb = 0
    dk0 = jnp.zeros((block_k, d), jnp.float32)
    dv0 = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(first_qb, nqb, body, (dk0, dv0))
    dk_ref[...] = dk  # q already carried sm_scale
    dv_ref[...] = dv


def _fit_block(block, s):
    """Largest power-of-two-ish block <= ``block`` that divides ``s``.

    XLA dynamic-slice clamps out-of-range starts, so a K/V tile that
    overhangs the sequence would silently read shifted rows; snapping the
    tile size to a divisor of ``s`` makes every tile exact instead.
    """
    b = min(block, s)
    while s % b:
        b -= 1
    return b


def _fwd(q, k, v, causal, block_q, block_k, interpret):
    b, h, s, d = q.shape
    block_q = _fit_block(block_q, s)
    block_k = _fit_block(block_k, s)
    sm_scale = 1.0 / (d ** 0.5)
    grid = (b, h, pl.cdiv(s, block_q))
    kernel = functools.partial(
        _fwd_kernel, block_k=block_k, causal=causal, sm_scale=sm_scale)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def _bwd(q, k, v, o, lse, do, causal, block_q, block_k, interpret):
    b, h, s, d = q.shape
    block_q = _fit_block(block_q, s)
    block_k = _fit_block(block_k, s)
    sm_scale = 1.0 / (d ** 0.5)
    delta = jnp.sum(do * o, axis=-1)  # [b, h, s]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_k=block_k, causal=causal, sm_scale=sm_scale),
        grid=(b, h, pl.cdiv(s, block_q)),
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, block_q), lambda bi, hi, qi: (bi, hi, qi)),
            pl.BlockSpec((None, None, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=block_q, causal=causal, sm_scale=sm_scale),
        grid=(b, h, pl.cdiv(s, block_k)),
        in_specs=[
            pl.BlockSpec((None, None, s, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((None, None, s, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, s), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((None, None, s), lambda bi, hi, ki: (bi, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((None, None, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True,
                    block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                    interpret=True):
    """Tiled online-softmax attention.  q, k, v: f32[B, H, S, D] -> f32[B, H, S, D].

    Differentiable via a custom VJP whose backward pass is itself two Pallas
    kernels (dQ, and dK/dV).  ``interpret=True`` is required on CPU PJRT.
    """
    o, _ = _fwd(q, k, v, causal, block_q, block_k, interpret)
    return o


def _flash_fwd_rule(q, k, v, causal, block_q, block_k, interpret):
    o, lse = _fwd(q, k, v, causal, block_q, block_k, interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd(q, k, v, o, lse, do, causal, block_q, block_k, interpret)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_lse(q, k, v, causal=True, block_q=DEFAULT_BLOCK_Q,
              block_k=DEFAULT_BLOCK_K, interpret=True):
    """Expose the forward pass's log-sum-exp rows (tested vs mha_lse_ref)."""
    _, lse = _fwd(q, k, v, causal, block_q, block_k, interpret)
    return lse
