"""Fused Adam update as a Pallas kernel.

This is the Layer-1 hot-spot of the parameter-server side of the workload:
each PS shard applies this update to its flat parameter chunk every step.
Fusing p/m/v into one kernel pass means each operand streams HBM->VMEM
exactly once per step (vs. >=6 passes for the naive jnp expression before
XLA fusion); on TPU the whole update is VPU-bound and the BlockSpec below
tiles the vectors so each program touches one VMEM-resident block.

Checked against ``ref.adam_ref`` by pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-program block length.  8192 f32 x 5 operands = 160 KiB of VMEM per
# program, comfortably inside a TensorCore's ~16 MiB while long enough to
# amortize grid overhead.
DEFAULT_BLOCK = 8192


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, step_ref, lr_ref,
                 p_out, m_out, v_out, *, beta1, beta2, eps):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    step = step_ref[0]
    lr = lr_ref[0]
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    # Bias correction: beta**step via exp(step * log(beta)) keeps the whole
    # kernel elementwise (no integer powers in the loop body).
    c1 = 1.0 - jnp.exp(step * jnp.log(beta1))
    c2 = 1.0 - jnp.exp(step * jnp.log(beta2))
    mhat = m2 / c1
    vhat = v2 / c2
    p_out[...] = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    m_out[...] = m2
    v_out[...] = v2


def adam_update(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                block=DEFAULT_BLOCK, interpret=True):
    """Fused Adam step over flat f32[N] vectors.

    Args:
      p, g, m, v: f32[N] (N need not be a multiple of ``block``; the vectors
        are zero-padded internally and the pad lanes provably stay zero).
      step: f32 scalar (1-based).
      lr: f32 scalar.

    Returns:
      (p', m', v') each f32[N].
    """
    n = p.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        z = jnp.zeros((pad,), p.dtype)
        p, g, m, v = (jnp.concatenate([a, z]) for a in (p, g, m, v))
    step = jnp.asarray(step, jnp.float32).reshape(1)
    lr = jnp.asarray(lr, jnp.float32).reshape(1)
    grid = (p.shape[0] // block,)
    kernel = functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    p2, m2, v2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scalar, scalar],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct(p.shape, jnp.float32)] * 3,
        interpret=interpret,
    )(p, g, m, v, step, lr)
    if pad:
        p2, m2, v2 = p2[:n], m2[:n], v2[:n]
    return p2, m2, v2
