"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signals: every Pallas kernel in this package
is checked against these references by pytest/hypothesis (see
python/tests/).  They are deliberately written as straight-line jnp with no
tiling so they are easy to audit.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, causal=True):
    """Multi-head attention reference.

    Args:
      q, k, v: f32[B, H, S, D]
      causal: apply a lower-triangular mask.

    Returns:
      f32[B, H, S, D]
    """
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def mha_lse_ref(q, k, v, causal=True):
    """Log-sum-exp rows of the attention logits (used by the flash bwd)."""
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    return jax.scipy.special.logsumexp(logits, axis=-1)


def adam_ref(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Adam update reference (Kingma & Ba), bias-corrected.

    Args:
      p, g, m, v: f32[N] parameter / gradient / first / second moment.
      step: f32 scalar, 1-based step count.
      lr: f32 scalar learning rate.

    Returns:
      (p', m', v')
    """
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**step)
    vhat = v2 / (1.0 - beta2**step)
    p2 = p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p2, m2, v2
