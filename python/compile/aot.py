"""AOT pipeline: lower the L2/L1 computations to HLO text artifacts.

Interchange format is HLO **text**, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  (See /opt/xla-example/README.md.)

Per preset this emits into ``artifacts/<preset>/``:

  worker_step.hlo.txt   (params f32[N], tokens i32[B,S+1]) -> (loss, grads)
  eval_loss.hlo.txt     (params f32[N], tokens i32[B,S+1]) -> (loss,)
  init_params.hlo.txt   (seed u32)                         -> (params f32[N],)
  ps_adam.hlo.txt       (p,g,m,v f32[C], step f32, lr f32) -> (p',m',v')
  meta.json             model dims, N, chunk length C, Adam hypers

Python runs ONCE at build time (``make artifacts``); the Rust binary loads
these artifacts via PJRT and is self-contained afterwards.
"""

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Flat parameter chunk length per PS shard unit.  Shards hold
# ceil(share / CHUNK) chunks; the tail chunk is zero-padded (pad lanes stay
# exactly zero under Adam with zero grads — tested in test_adam.py).
DEFAULT_CHUNK = 1 << 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(preset: str, chunk: int = DEFAULT_CHUNK):
    """Return {artifact_name: hlo_text} plus the meta dict for one preset."""
    cfg = M.PRESETS[preset]
    n = M.n_params(cfg)
    chunk = min(chunk, n)

    params_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    chunk_spec = jax.ShapeDtypeStruct((chunk,), jnp.float32)
    scalar_f32 = jax.ShapeDtypeStruct((), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.uint32)

    def worker_step(p, t):
        return M.worker_step(cfg, p, t)

    def eval_loss(p, t):
        return (M.eval_loss(cfg, p, t),)

    def init_fn(seed):
        return (M.init_params(cfg, seed),)

    def ps_adam(p, g, m, v, step, lr):
        return M.adam_chunk_update(p, g, m, v, step, lr)

    arts = {
        "worker_step": jax.jit(worker_step).lower(params_spec, tokens_spec),
        "eval_loss": jax.jit(eval_loss).lower(params_spec, tokens_spec),
        "init_params": jax.jit(init_fn).lower(seed_spec),
        "ps_adam": jax.jit(ps_adam).lower(
            chunk_spec, chunk_spec, chunk_spec, chunk_spec, scalar_f32, scalar_f32),
    }
    meta = {
        "preset": preset,
        "model": dataclasses.asdict(cfg),
        "n_params": n,
        "chunk_len": chunk,
        "adam": {"beta1": 0.9, "beta2": 0.999, "eps": 1e-8},
        "artifacts": {k: f"{k}.hlo.txt" for k in arts},
        # IO signatures the Rust runtime asserts against (shape, dtype).
        "signatures": {
            "worker_step": {"in": [["f32", [n]], ["i32", [cfg.batch, cfg.seq_len + 1]]],
                            "out": [["f32", []], ["f32", [n]]]},
            "eval_loss": {"in": [["f32", [n]], ["i32", [cfg.batch, cfg.seq_len + 1]]],
                          "out": [["f32", []]]},
            "init_params": {"in": [["u32", []]], "out": [["f32", [n]]]},
            "ps_adam": {"in": [["f32", [chunk]]] * 4 + [["f32", []], ["f32", []]],
                        "out": [["f32", [chunk]]] * 3},
        },
    }
    return {k: to_hlo_text(v) for k, v in arts.items()}, meta


def emit(preset: str, out_dir: str, chunk: int = DEFAULT_CHUNK) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    texts, meta = lower_artifacts(preset, chunk)
    for name, text in texts.items():
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    total = sum(len(t) for t in texts.values())
    print(f"[aot] preset={preset} n_params={meta['n_params']} "
          f"chunk={meta['chunk_len']} -> {out_dir} ({total} chars of HLO)")
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root (per-preset subdirs are created)")
    ap.add_argument("--presets", default="tiny,small",
                    help="comma-separated preset names (see model.PRESETS)")
    ap.add_argument("--chunk", type=int, default=DEFAULT_CHUNK)
    args = ap.parse_args()
    for preset in args.presets.split(","):
        preset = preset.strip()
        if preset not in M.PRESETS:
            raise SystemExit(f"unknown preset {preset!r}; have {list(M.PRESETS)}")
        emit(preset, os.path.join(args.out, preset), args.chunk)


if __name__ == "__main__":
    main()
