"""Layer-2: the transformer LM that TonY's distributed job trains.

A pre-LN causal transformer language model written in JAX, with the
attention inner loop delegated to the Layer-1 Pallas kernel
(``kernels.flash_attention``).  Parameters live in a **flat f32[N] vector**
with a deterministic layout (``param_specs``) so the Rust parameter-server
shards (rust/src/framework/) can slice, shard, pad, and checkpoint them
without knowing anything about the model structure.

Everything here is build-time only: ``compile.aot`` lowers
``worker_step`` / ``adam_chunk_update`` / ``eval_loss`` / ``init_params``
to HLO text once, and the Rust runtime executes the artifacts via PJRT.
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.adam import adam_update
from .kernels.flash_attention import flash_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyperparameters (fixed at AOT time)."""

    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    seq_len: int = 64
    batch: int = 4
    block_q: int = 64
    block_k: int = 64

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# Layer parameters are stacked along a leading n_layers axis so the forward
# pass can lax.scan over layers (bounds HLO size for deep presets) and the
# flat layout stays independent of depth-unrolling decisions.
def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) layout of the flat parameter vector.

    The order here IS the wire format: Rust's PS shards and checkpoints
    address parameters purely by offset into the flat vector.
    """
    L, D, F, V, S = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    return [
        ("embed", (V, D)),
        ("pos", (S, D)),
        ("ln1_scale", (L, D)),
        ("ln1_bias", (L, D)),
        ("wq", (L, D, D)),
        ("wk", (L, D, D)),
        ("wv", (L, D, D)),
        ("wo", (L, D, D)),
        ("ln2_scale", (L, D)),
        ("ln2_bias", (L, D)),
        ("w_up", (L, D, F)),
        ("b_up", (L, F)),
        ("w_down", (L, F, D)),
        ("b_down", (L, D)),
        ("lnf_scale", (D,)),
        ("lnf_bias", (D,)),
    ]


def n_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_specs(cfg):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


def unpack(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    """Slice the flat vector back into named parameter arrays."""
    out = {}
    off = 0
    for name, shape in param_specs(cfg):
        size = 1
        for s in shape:
            size *= s
        out[name] = flat[off:off + size].reshape(shape)
        off += size
    assert off == flat.shape[0], (off, flat.shape)
    return out


def pack(cfg: ModelConfig, params: Dict[str, jax.Array]) -> jax.Array:
    """Flatten named parameters into the canonical flat vector."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_specs(cfg)])


def init_params(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """Initialize the flat parameter vector from a uint32 seed.

    Scaled-normal init: embeddings/projections at 1/sqrt(fan_in), residual
    output projections additionally shrunk by 1/sqrt(2*L) (GPT-2 style),
    layernorm at scale=1 bias=0.
    """
    key = jax.random.PRNGKey(seed)
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    parts = []
    for (name, shape), k in zip(specs, keys):
        if name.startswith("ln") or name.endswith("_bias") or name.startswith("b_"):
            val = (jnp.ones(shape, jnp.float32) if "scale" in name
                   else jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            if name in ("wo", "w_down"):
                std = std * resid_scale
            val = std * jax.random.normal(k, shape, jnp.float32)
        parts.append(val.reshape(-1))
    return jnp.concatenate(parts)


def _layernorm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _block(cfg: ModelConfig, x, layer):
    """One pre-LN transformer block.  x: [B, S, D]."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.d_head

    h = _layernorm(x, layer["ln1_scale"], layer["ln1_bias"])
    q = (h @ layer["wq"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    k = (h @ layer["wk"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    v = (h @ layer["wv"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    attn = flash_attention(q, k, v, True, cfg.block_q, cfg.block_k)
    attn = attn.transpose(0, 2, 1, 3).reshape(B, S, D)
    x = x + attn @ layer["wo"]

    h = _layernorm(x, layer["ln2_scale"], layer["ln2_bias"])
    h = _gelu(h @ layer["w_up"] + layer["b_up"])
    x = x + h @ layer["w_down"] + layer["b_down"]
    return x


_LAYER_KEYS = ("ln1_scale", "ln1_bias", "wq", "wk", "wv", "wo",
               "ln2_scale", "ln2_bias", "w_up", "b_up", "w_down", "b_down")


def forward(cfg: ModelConfig, flat_params: jax.Array, tokens: jax.Array) -> jax.Array:
    """Logits for a token batch.  tokens: i32[B, S] -> f32[B, S, V]."""
    p = unpack(cfg, flat_params)
    x = p["embed"][tokens] + p["pos"][None, :tokens.shape[1]]

    stacked = {k: p[k] for k in _LAYER_KEYS}

    def scan_body(x, layer):
        return _block(cfg, x, layer), None

    x, _ = jax.lax.scan(scan_body, x, stacked)
    x = _layernorm(x, p["lnf_scale"], p["lnf_bias"])
    # Weight-tied output head.
    return x @ p["embed"].T


def loss_fn(cfg: ModelConfig, flat_params: jax.Array, tokens: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy.  tokens: i32[B, S+1] -> f32 scalar."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, flat_params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def worker_step(cfg: ModelConfig, flat_params: jax.Array, tokens: jax.Array):
    """The worker-task hot path: (params, batch) -> (loss, grads).

    This is what each TonY worker container executes every step via PJRT.
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(flat_params)
    return loss, grads


def eval_loss(cfg: ModelConfig, flat_params: jax.Array, tokens: jax.Array):
    """Evaluation-only loss (no backward), used by the chief/eval task."""
    return loss_fn(cfg, flat_params, tokens)


def adam_chunk_update(chunk, grad, m, v, step, lr,
                      beta1=0.9, beta2=0.999, eps=1e-8):
    """The PS-task hot path: fused Adam over one flat parameter chunk.

    Zero-padded tail lanes provably stay zero: g=0 with m=v=0 yields an
    exactly-zero update, so shard padding never leaks into the model.
    """
    return adam_update(chunk, grad, m, v, step, lr,
                       beta1=beta1, beta2=beta2, eps=eps)


PRESETS: Dict[str, ModelConfig] = {
    # Unit tests / microbenches: compiles in seconds.
    "tiny": ModelConfig(vocab=256, d_model=64, n_heads=4, n_layers=2,
                        d_ff=256, seq_len=64, batch=4),
    # The recorded end-to-end training run (examples/e2e_train.rs): ~3.4M
    # params, <1 s/step on CPU PJRT.
    "small": ModelConfig(vocab=256, d_model=256, n_heads=8, n_layers=4,
                         d_ff=1024, seq_len=128, batch=8),
    # ~19M params: the config the C6 throughput bench scales to.
    "medium": ModelConfig(vocab=256, d_model=512, n_heads=8, n_layers=6,
                          d_ff=2048, seq_len=128, batch=8),
    # ~107M params (GPT-2-small class): smoke-run only on this CPU testbed;
    # see DESIGN.md §5 for the substitution note.
    "large": ModelConfig(vocab=32000, d_model=768, n_heads=12, n_layers=12,
                         d_ff=3072, seq_len=256, batch=4, block_q=128, block_k=128),
}
