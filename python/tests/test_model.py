"""L2 transformer model: shapes, pack/unpack, gradient sanity, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2,
                    d_ff=64, seq_len=16, batch=2, block_q=16, block_k=16)


def rand_tokens(cfg, seed=0, extra=1):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq_len + extra), 0, cfg.vocab)


def test_param_count_and_layout():
    n = M.n_params(CFG)
    specs = M.param_specs(CFG)
    assert n == sum(int(np.prod(s)) for _, s in specs)
    # Layout is stable and starts with the embedding.
    assert specs[0][0] == "embed"
    assert specs[0][1] == (CFG.vocab, CFG.d_model)


def test_pack_unpack_round_trip():
    flat = M.init_params(CFG, jnp.uint32(0))
    assert flat.shape == (M.n_params(CFG),)
    tree = M.unpack(CFG, flat)
    flat2 = M.pack(CFG, tree)
    np.testing.assert_array_equal(flat, flat2)


def test_init_is_seeded():
    a = M.init_params(CFG, jnp.uint32(1))
    b = M.init_params(CFG, jnp.uint32(1))
    c = M.init_params(CFG, jnp.uint32(2))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # Layernorm scales init to 1, biases to 0.
    tree = M.unpack(CFG, a)
    np.testing.assert_array_equal(tree["lnf_scale"], np.ones(CFG.d_model))
    np.testing.assert_array_equal(tree["lnf_bias"], np.zeros(CFG.d_model))


def test_forward_shapes_and_loss_level():
    flat = M.init_params(CFG, jnp.uint32(0))
    tokens = rand_tokens(CFG, extra=0)
    logits = M.forward(CFG, flat, tokens)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    loss = M.loss_fn(CFG, flat, rand_tokens(CFG))
    # Random init on uniform tokens: within ~0.7 nat of ln(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.7


def test_worker_step_outputs():
    flat = M.init_params(CFG, jnp.uint32(0))
    loss, grads = M.worker_step(CFG, flat, rand_tokens(CFG))
    assert grads.shape == flat.shape
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(grads)).all()
    assert float(jnp.linalg.norm(grads)) > 1e-6


def test_grads_match_finite_differences():
    # Tiny config so FD is meaningful.
    cfg = M.ModelConfig(vocab=16, d_model=8, n_heads=2, n_layers=1,
                        d_ff=16, seq_len=8, batch=1, block_q=8, block_k=8)
    flat = M.init_params(cfg, jnp.uint32(3))
    tokens = jax.random.randint(jax.random.PRNGKey(0), (1, 9), 0, 16)
    _, grads = M.worker_step(cfg, flat, tokens)
    rng = np.random.default_rng(0)
    idxs = rng.choice(flat.shape[0], size=8, replace=False)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros_like(flat).at[i].set(eps)
        lp = float(M.loss_fn(cfg, flat + e, tokens))
        lm = float(M.loss_fn(cfg, flat - e, tokens))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - float(grads[i])) < 5e-2, f"param {i}: fd={fd} ad={float(grads[i])}"


def test_causality_of_lm():
    # Changing future tokens must not change earlier logits.
    flat = M.init_params(CFG, jnp.uint32(0))
    tokens = rand_tokens(CFG, extra=0)
    logits1 = M.forward(CFG, flat, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab)
    logits2 = M.forward(CFG, flat, tokens2)
    np.testing.assert_allclose(
        logits1[:, :-1], logits2[:, :-1], atol=1e-5, rtol=1e-4)


def test_adam_training_reduces_loss():
    # Full L2 loop in pure jax: worker_step + adam_chunk_update.
    cfg = CFG
    flat = M.init_params(cfg, jnp.uint32(0))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    tokens = rand_tokens(cfg, seed=1)
    first = float(M.loss_fn(cfg, flat, tokens))
    for step in range(1, 21):
        _, g = M.worker_step(cfg, flat, tokens)
        flat, m, v = M.adam_chunk_update(flat, g, m, v, float(step), 1e-2)
    last = float(M.loss_fn(cfg, flat, tokens))
    assert last < first - 0.5, f"no learning: {first} -> {last}"


@settings(max_examples=5, deadline=None)
@given(
    d_model=st.sampled_from([16, 32]),
    n_layers=st.integers(1, 3),
    seq=st.sampled_from([8, 16]),
)
def test_shape_sweep(d_model, n_layers, seq):
    cfg = M.ModelConfig(vocab=32, d_model=d_model, n_heads=2, n_layers=n_layers,
                        d_ff=2 * d_model, seq_len=seq, batch=2,
                        block_q=min(seq, 16), block_k=min(seq, 16))
    flat = M.init_params(cfg, jnp.uint32(0))
    loss, grads = M.worker_step(cfg, flat, rand_tokens(cfg))
    assert grads.shape == (M.n_params(cfg),)
    assert np.isfinite(float(loss))


def test_presets_are_wellformed():
    for name, cfg in M.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert M.n_params(cfg) > 0
    # Documented size classes.
    assert 3.0e6 < M.n_params(M.PRESETS["small"]) < 4.0e6
    assert 1.5e7 < M.n_params(M.PRESETS["medium"]) < 2.5e7
    assert 0.9e8 < M.n_params(M.PRESETS["large"]) < 1.3e8
