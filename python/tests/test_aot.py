"""AOT pipeline: HLO-text emission, meta.json contract, artifact shapes."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = aot.emit("tiny", str(out / "tiny"))
    return out / "tiny", meta


def test_all_artifacts_emitted(tiny_artifacts):
    d, meta = tiny_artifacts
    for name in ["worker_step", "eval_loss", "init_params", "ps_adam"]:
        path = d / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        # HLO text, not proto bytes, and an entry computation is present.
        assert "HloModule" in text
        assert "ENTRY" in text
    assert (d / "meta.json").exists()


def test_meta_contract(tiny_artifacts):
    d, meta = tiny_artifacts
    on_disk = json.loads((d / "meta.json").read_text())
    cfg = M.PRESETS["tiny"]
    assert on_disk["n_params"] == M.n_params(cfg)
    assert on_disk["model"]["d_model"] == cfg.d_model
    assert on_disk["chunk_len"] <= on_disk["n_params"]
    sigs = on_disk["signatures"]
    n = on_disk["n_params"]
    assert sigs["worker_step"]["in"][0] == ["f32", [n]]
    assert sigs["worker_step"]["in"][1] == ["i32", [cfg.batch, cfg.seq_len + 1]]
    assert sigs["worker_step"]["out"][0] == ["f32", []]
    assert sigs["ps_adam"]["in"][0][1] == [on_disk["chunk_len"]]


def test_hlo_has_no_python_callbacks(tiny_artifacts):
    """interpret=True must lower pallas to plain HLO — a custom-call would
    mean the Rust CPU client cannot run it."""
    d, _ = tiny_artifacts
    for name in ["worker_step", "ps_adam"]:
        text = (d / f"{name}.hlo.txt").read_text()
        assert "custom-call" not in text or "Sharding" in text, (
            f"{name} contains a non-trivial custom-call")


def test_emitted_module_roundtrips_through_jax(tiny_artifacts):
    """Execute the lowered worker_step via jax and compare against the
    direct (unlowered) model — the same check the Rust side repeats."""
    cfg = M.PRESETS["tiny"]
    n = M.n_params(cfg)
    lowered = jax.jit(lambda p, t: M.worker_step(cfg, p, t)).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32),
    )
    compiled = lowered.compile()
    params = M.init_params(cfg, jnp.uint32(0))
    tokens = jax.random.randint(
        jax.random.PRNGKey(0), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)
    loss_c, grads_c = compiled(params, tokens)
    loss_d, grads_d = M.worker_step(cfg, params, tokens)
    assert abs(float(loss_c) - float(loss_d)) < 1e-5
    import numpy as np
    np.testing.assert_allclose(grads_c, grads_d, atol=1e-5, rtol=1e-4)


def test_unknown_preset_rejected():
    with pytest.raises(KeyError):
        aot.lower_artifacts("nonexistent")
