"""Invariants of the analytic TPU estimator (L1 §Perf substitute)."""

from hypothesis import given, settings, strategies as st

from compile.kernels.estimate import (
    AttnShape, VMEM_BYTES, best_config, estimate_attention, sweep)


def test_vmem_monotonic_in_blocks():
    s = AttnShape(2048, 64)
    small = estimate_attention(s, 32, 32)
    big = estimate_attention(s, 256, 256)
    assert small.vmem_bytes < big.vmem_bytes


def test_mxu_utilization_peaks_at_multiples_of_128():
    s = AttnShape(2048, 128)
    full = estimate_attention(s, 128, 128)
    partial = estimate_attention(s, 96, 96)
    assert full.mxu_utilization > partial.mxu_utilization
    assert full.mxu_utilization == 1.0


def test_best_config_fits_and_dominates():
    for shape in [AttnShape(128, 32), AttnShape(2048, 64), AttnShape(8192, 128)]:
        best = best_config(shape)
        assert best.fits_vmem
        for e in sweep(shape):
            if e.fits_vmem:
                assert best.est_tflops >= e.est_tflops


@settings(max_examples=50, deadline=None)
@given(
    seq=st.sampled_from([64, 128, 256, 1024, 4096]),
    d=st.sampled_from([32, 64, 128]),
    bq=st.sampled_from([32, 64, 128, 256]),
    bk=st.sampled_from([32, 64, 128, 256]),
)
def test_estimates_are_sane(seq, d, bq, bk):
    e = estimate_attention(AttnShape(seq, d), bq, bk)
    assert 0 < e.mxu_utilization <= 1.0
    assert e.vmem_bytes > 0
    assert e.est_tflops > 0
    assert e.roofline_fraction <= 1.0 + 1e-9
    assert e.fits_vmem == (e.vmem_bytes <= VMEM_BYTES)
    # Blocks are clamped to seq.
    assert e.block_q <= seq and e.block_k <= seq


def test_flash_hbm_traffic_beats_naive():
    # Naive attention materializes the s x s score matrix in HBM.
    shape = AttnShape(4096, 64)
    e = estimate_attention(shape, 128, 128)
    # Naive round-trips the s x s scores and probs through HBM:
    # write scores, read for softmax, write probs, read for P@V.
    naive_bytes = 4 * (4 * shape.seq * shape.seq + 4 * shape.seq * shape.d_head)
    assert e.hbm_bytes_per_head < naive_bytes / 3
