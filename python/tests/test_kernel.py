"""Pallas flash-attention kernel vs the pure-jnp oracle.

This is the CORE L1 correctness signal: forward, LSE, and the custom-VJP
backward (itself two Pallas kernels) are checked against ``ref.mha_ref``
and jnp autodiff across hypothesis-driven shape sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import flash_attention, flash_lse
from compile.kernels.ref import mha_lse_ref, mha_ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5
RTOL = 2e-4


def rand_qkv(key, b, h, s, d, scale=1.0):
    kq, kk, kv = jax.random.split(key, 3)
    q = scale * jax.random.normal(kq, (b, h, s, d), jnp.float32)
    k = scale * jax.random.normal(kk, (b, h, s, d), jnp.float32)
    v = scale * jax.random.normal(kv, (b, h, s, d), jnp.float32)
    return q, k, v


def test_forward_matches_ref_basic():
    q, k, v = rand_qkv(jax.random.PRNGKey(0), 2, 4, 128, 32)
    np.testing.assert_allclose(
        flash_attention(q, k, v), mha_ref(q, k, v), atol=ATOL, rtol=RTOL)


def test_lse_matches_ref():
    q, k, v = rand_qkv(jax.random.PRNGKey(1), 1, 2, 64, 16)
    np.testing.assert_allclose(
        flash_lse(q, k, v), mha_lse_ref(q, k, v), atol=ATOL, rtol=RTOL)


def test_non_causal_mode():
    q, k, v = rand_qkv(jax.random.PRNGKey(2), 1, 2, 64, 16)
    np.testing.assert_allclose(
        flash_attention(q, k, v, False),
        mha_ref(q, k, v, causal=False),
        atol=ATOL,
        rtol=RTOL,
    )


def test_causal_masking_is_real():
    # Causal output at position i must not depend on positions > i.
    key = jax.random.PRNGKey(3)
    q, k, v = rand_qkv(key, 1, 1, 64, 16)
    o1 = flash_attention(q, k, v)
    # Perturb the FUTURE half of k/v; first half of outputs must not move.
    k2 = k.at[:, :, 32:].add(100.0)
    v2 = v.at[:, :, 32:].add(-50.0)
    o2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(o1[:, :, :32], o2[:, :, :32], atol=1e-6)
    assert not np.allclose(o1[:, :, 32:], o2[:, :, 32:])


def test_gradients_match_ref():
    q, k, v = rand_qkv(jax.random.PRNGKey(4), 2, 2, 64, 16)

    def f(q, k, v):
        return jnp.sum(jnp.tanh(flash_attention(q, k, v)))

    def fr(q, k, v):
        return jnp.sum(jnp.tanh(mha_ref(q, k, v)))

    g = jax.grad(f, (0, 1, 2))(q, k, v)
    gr = jax.grad(fr, (0, 1, 2))(q, k, v)
    for a, b, name in zip(g, gr, "qkv"):
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=1e-3, err_msg=f"d{name}")


def test_gradients_non_causal():
    q, k, v = rand_qkv(jax.random.PRNGKey(5), 1, 2, 32, 16)
    g = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v, False) ** 2))(q)
    gr = jax.grad(lambda q: jnp.sum(mha_ref(q, k, v, causal=False) ** 2))(q)
    np.testing.assert_allclose(g, gr, atol=5e-5, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_pow=st.integers(4, 8),  # seq 16..256
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_hypothesis_sweep(b, h, s_pow, d, causal, seed):
    s = 1 << s_pow
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), b, h, s, d)
    np.testing.assert_allclose(
        flash_attention(q, k, v, causal),
        mha_ref(q, k, v, causal=causal),
        atol=ATOL,
        rtol=RTOL,
    )


@settings(max_examples=8, deadline=None)
@given(
    block_q=st.sampled_from([16, 32, 64, 128]),
    block_k=st.sampled_from([16, 32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_shape_independence(block_q, block_k, seed):
    """Numerics must not depend on the chosen tiling."""
    q, k, v = rand_qkv(jax.random.PRNGKey(seed), 1, 2, 128, 16)
    out = flash_attention(q, k, v, True, block_q, block_k)
    np.testing.assert_allclose(out, mha_ref(q, k, v), atol=ATOL, rtol=RTOL)


def test_seq_not_multiple_of_block():
    # s=96 with block 64: cdiv grid + causal bounds must stay correct.
    q, k, v = rand_qkv(jax.random.PRNGKey(6), 1, 1, 96, 16)
    np.testing.assert_allclose(
        flash_attention(q, k, v, True, 64, 64), mha_ref(q, k, v), atol=ATOL, rtol=RTOL)


def test_numerical_stability_large_logits():
    # Online softmax must survive logits ~ +-30 without overflow.
    q, k, v = rand_qkv(jax.random.PRNGKey(7), 1, 1, 64, 16, scale=10.0)
    out = flash_attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, mha_ref(q, k, v), atol=1e-4, rtol=1e-3)


def test_jit_compatible():
    q, k, v = rand_qkv(jax.random.PRNGKey(8), 1, 2, 64, 16)
    jitted = jax.jit(lambda q, k, v: flash_attention(q, k, v))
    np.testing.assert_allclose(jitted(q, k, v), mha_ref(q, k, v), atol=ATOL, rtol=RTOL)
