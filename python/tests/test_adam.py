"""Fused-Adam Pallas kernel vs the reference, including the shard-padding
fixed-point invariant the Rust PS relies on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.adam import adam_update
from compile.kernels.ref import adam_ref

jax.config.update("jax_platform_name", "cpu")


def rand_state(seed, n):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.normal(k1, (n,))
    g = jax.random.normal(k2, (n,))
    m = 0.1 * jax.random.normal(k3, (n,))
    v = jnp.abs(jax.random.normal(k4, (n,)))
    return p, g, m, v


def test_matches_ref_basic():
    p, g, m, v = rand_state(0, 10_000)
    out = adam_update(p, g, m, v, 5.0, 1e-3)
    ref = adam_ref(p, g, m, v, 5.0, 1e-3)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 50_000),
    step=st.integers(1, 10_000),
    lr_exp=st.integers(-6, -1),
    block=st.sampled_from([64, 1024, 8192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, step, lr_exp, block, seed):
    p, g, m, v = rand_state(seed, n)
    lr = 10.0 ** lr_exp
    out = adam_update(p, g, m, v, float(step), lr, block=block)
    ref = adam_ref(p, g, m, v, float(step), lr)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


def test_zero_everything_is_fixed_point():
    """Pad lanes (p=g=m=v=0) must stay exactly zero — the Rust PS pads
    every tail chunk with zeros and ships the whole chunk back."""
    n = 1000
    z = jnp.zeros((n,))
    p2, m2, v2 = adam_update(z, z, z, z, 1.0, 0.1)
    assert (np.asarray(p2) == 0).all()
    assert (np.asarray(m2) == 0).all()
    assert (np.asarray(v2) == 0).all()


def test_padding_lanes_do_not_leak():
    # n not a multiple of block: internal pad must not alter real lanes.
    n = 100
    p, g, m, v = rand_state(1, n)
    small = adam_update(p, g, m, v, 3.0, 1e-2, block=64)     # pads to 128
    exact = adam_update(p, g, m, v, 3.0, 1e-2, block=100)    # no pad
    for a, b in zip(small, exact):
        np.testing.assert_allclose(a, b, atol=1e-6)


def test_bias_correction_step1():
    # At step 1 with m=v=0: p' = p - lr * sign-ish(g) (mhat = g exactly).
    n = 256
    p = jnp.zeros((n,))
    g = jnp.ones((n,))
    z = jnp.zeros((n,))
    p2, m2, v2 = adam_update(p, g, z, z, 1.0, 0.5)
    # mhat = g, vhat = g^2 -> update = lr * 1/(1+eps) ~ lr
    np.testing.assert_allclose(p2, -0.5 * np.ones(n), atol=1e-4)
    np.testing.assert_allclose(m2, 0.1 * np.ones(n), atol=1e-6)


def test_determinism():
    p, g, m, v = rand_state(2, 5000)
    a = adam_update(p, g, m, v, 7.0, 1e-3)
    b = adam_update(p, g, m, v, 7.0, 1e-3)
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
