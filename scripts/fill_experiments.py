#!/usr/bin/env python3
"""Splice measured outputs into EXPERIMENTS.md.

Reads:
  bench_output.txt            (cargo bench | tee)
  runs/e2e_small_record.md    (examples/e2e_train.rs)
  the L1 estimator sweep      (computed in-process)

and replaces the `<!-- BENCH:x -->`, `<!-- E2E -->`, `<!-- L1SWEEP -->`
placeholder blocks.  Idempotent: rerunning replaces the fenced block that
follows each marker.
"""

import io
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MARKER_TO_TITLE = {
    "FIG1": "FIG1: lifecycle stage latency",
    "C1": "C1: contention",
    "C2": "C2: cluster-spec assembly",
    "C3": "C3: AM heartbeat fan-in",
    "C4": "C4: recovery after worker kill",
    "C5": "C5: CapacityScheduler pass",
    "C6": "C6: full-stack training throughput",
    "C7": "C7: Dr. Elephant heuristic quality",
    "PERF": "PERF: hot-path microbenches",
}


def extract_table(bench_text: str, title_prefix: str) -> str:
    """Grab a `### title` block (including trailing notes) from bench output."""
    lines = bench_text.splitlines()
    out = []
    grabbing = False
    for i, line in enumerate(lines):
        if line.startswith("### ") and title_prefix in line:
            grabbing = True
            out.append(line)
            continue
        if grabbing:
            if line.startswith("### ") or line.startswith("     Running") or line.startswith("   Compiling"):
                break
            out.append(line)
    text = "\n".join(out).rstrip()
    return text if text else "(bench output not found — rerun `cargo bench`)"


def splice(md: str, marker: str, content: str) -> str:
    pattern = re.compile(
        r"(<!-- " + re.escape(marker) + r" -->\n```\n).*?(\n```)", re.DOTALL)
    repl = r"\1" + content.replace("\\", "\\\\") + r"\2"
    new, n = pattern.subn(repl, md)
    if n == 0:
        print(f"warning: marker {marker} not found", file=sys.stderr)
        return md
    return new


def l1_sweep() -> str:
    sys.path.insert(0, os.path.join(ROOT, "python"))
    from compile.kernels import estimate as est  # noqa: E402

    buf = io.StringIO()
    stdout = sys.stdout
    sys.stdout = buf
    try:
        est.main()
    finally:
        sys.stdout = stdout
    return buf.getvalue().strip()


def main():
    md_path = os.path.join(ROOT, "EXPERIMENTS.md")
    md = open(md_path).read()

    bench_path = os.path.join(ROOT, "bench_output.txt")
    if os.path.exists(bench_path):
        bench = open(bench_path).read()
        for marker, title in MARKER_TO_TITLE.items():
            md = splice(md, f"BENCH:{marker}", extract_table(bench, title))
    else:
        print("warning: bench_output.txt missing; bench tables not updated",
              file=sys.stderr)

    rec_path = os.path.join(ROOT, "runs", "e2e_small_record.md")
    if os.path.exists(rec_path):
        md = splice(md, "E2E", open(rec_path).read().strip())

    md = splice(md, "L1SWEEP", l1_sweep())

    open(md_path, "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
