#!/usr/bin/env bash
# Tier-1 CI gate: release build + full test suite + formatting.
# Usage: scripts/ci.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p tony

echo "==> cargo test --doc (rustdoc examples)"
cargo test --doc -q -p tony

echo "==> fault-tolerance example (surgical task + node recovery, sim mode)"
cargo run --release --example fault_tolerance

echo "==> recovery bench smoke (surgical vs full restart, 4 workers)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_recovery

echo "==> latency bench smoke (event-driven vs poll fallback + trace overhead <5%)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_latency

echo "==> contention bench smoke (gang mode deadlock-freedom at 2/8 jobs)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_contention

echo "==> every tony.scheduler.* key referenced in code is documented"
missing=0
for key in $(grep -rhoE '"tony\.scheduler\.[a-z0-9.-]+"' rust/src | tr -d '"' | sort -u); do
    if ! grep -q "$key" docs/CONFIGURATION.md; then
        echo "ERROR: $key is used in rust/src but missing from docs/CONFIGURATION.md"
        missing=1
    fi
    if ! grep -q "$key" docs/SCHEDULING.md; then
        echo "ERROR: $key is used in rust/src but missing from docs/SCHEDULING.md"
        missing=1
    fi
done
if [ "$missing" -ne 0 ]; then
    exit 1
fi

echo "==> every tony.trace.* key referenced in code is documented"
missing=0
for key in $(grep -rhoE '"tony\.trace\.[a-z0-9.-]+"' rust/src | tr -d '"' | sort -u); do
    if ! grep -q "$key" docs/CONFIGURATION.md; then
        echo "ERROR: $key is used in rust/src but missing from docs/CONFIGURATION.md"
        missing=1
    fi
    if ! grep -q "$key" docs/TRACING.md; then
        echo "ERROR: $key is used in rust/src but missing from docs/TRACING.md"
        missing=1
    fi
done
if [ "$missing" -ne 0 ]; then
    exit 1
fi

echo "==> no stray std::thread::sleep in rust/src (event-driven control plane)"
# The only allowed home is util/clock.rs: the SystemClock impl plus the
# explicit real_sleep() escape hatch for I/O backoff / simulated
# child-task cadences.  Everything else must block on WakeupBus waits.
if grep -rn "std::thread::sleep" rust/src --include='*.rs' | grep -v "^rust/src/util/clock.rs"; then
    echo "ERROR: stray std::thread::sleep outside util/clock.rs (route through Clock::sleep, WakeupBus, or real_sleep)"
    exit 1
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable; skipping cargo fmt --check"
fi

echo "CI gate passed."
