#!/usr/bin/env bash
# Tier-1 CI gate: release build + full test suite + formatting.
# Usage: scripts/ci.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p tony

echo "==> cargo test --doc (rustdoc examples)"
cargo test --doc -q -p tony

echo "==> fault-tolerance example (surgical task + node recovery, sim mode)"
cargo run --release --example fault_tolerance

echo "==> recovery bench smoke (surgical vs full restart, 4 workers)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_recovery

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable; skipping cargo fmt --check"
fi

echo "CI gate passed."
