#!/usr/bin/env bash
# Tier-1 CI gate: release build + full test suite + formatting.
# Usage: scripts/ci.sh   (from anywhere; cd's to the repo root)
set -euo pipefail

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps -p tony

echo "==> cargo test --doc (rustdoc examples)"
cargo test --doc -q -p tony

echo "==> fault-tolerance example (surgical task + node recovery, sim mode)"
cargo run --release --example fault_tolerance

echo "==> recovery bench smoke (surgical vs full restart, 4 workers)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_recovery

echo "==> latency bench smoke (event-driven vs poll fallback + trace overhead <5%)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_latency

echo "==> contention bench smoke (gang deadlock-freedom + elastic goodput >= rigid-only at 2/8 jobs)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_contention

echo "==> scheduler bench smoke (10k-node scenario: p99 allocate bound + indexed >= 10x linear)"
# The smoke mode asserts both gates internally: indexed p99 allocate
# round under TONY_SCHED_P99_MS (default 100 ms) and the indexed path
# >= 10x the measured linear baseline per grant.
TONY_BENCH_SMOKE=1 cargo bench --bench bench_scheduler

echo "==> crash-recovery suite (WAL crash points + mid-allocate-wave restart)"
# `cargo test -q` above already ran these; run them by name too so a
# durability regression is named in CI output, not buried in the batch.
cargo test -q --test crash_recovery
cargo test -q --test prop_wal

echo "==> elastic-jobs suite (grow/shrink waves, released exits, shrink-over-preempt)"
# Also in the batch above; named so a resize-invariant regression is
# visible in CI output.
cargo test -q --test elastic_jobs

echo "==> gateway bench smoke (multi-tenant throughput + WAL submit-path overhead)"
TONY_BENCH_SMOKE=1 cargo bench --bench bench_gateway

echo "==> tony-lint (lock order, blocking-under-lock, config/metric drift, sleep ban)"
# Replaces the old grep gates (tony.scheduler.*/tony.trace.* doc sweeps,
# std::thread::sleep ban) with the real analyzer: docs/LINTS.md.  Prints
# per-rule counts; any error — or any warning, under --deny warnings —
# fails the gate.  rust/lint itself is excluded: its tests/fixtures/
# corpus is intentionally bad.
cargo run --release -q -p tony-lint -- --deny warnings \
    rust/src rust/benches rust/tests examples

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable; skipping cargo fmt --check"
fi

echo "CI gate passed."
